"""Per-kernel correctness: shape/dtype sweeps, Pallas (interpret) vs ref.py."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import autotune
from repro.core.grid_swizzle import SwizzleConfig
from repro.core.policy import make_policy
from repro.kernels.gemm import (Epilogue, Prologue, gemm, gemm_fused,
                                gemm_fused_ref, gemm_ref)
from repro.kernels.attention import (attention, attention_ref,
                                     flash_attention_fwd)
from repro.kernels.attention.ref import attention_ref_chunked
from repro.kernels.fused_norm import (dropout_residual_layernorm,
                                      fused_dropout_residual_layernorm_ref)
from repro.kernels.fused_norm.ref import dropout_keep_mask_ref
from repro.kernels.rope import rope, rope_ref, rope_tables

KEY = jax.random.PRNGKey(0)


class TestGemm:
    @pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 512, 384),
                                       (512, 256, 1280), (384, 384, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, n, k, dtype):
        a = jax.random.normal(KEY, (m, k), dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
        pol = make_policy("gemm", block_m=256, block_n=256, block_k=256)
        out = gemm(a, b, policy=pol, out_dtype=jnp.float32)
        ref = gemm_ref(a, b, jnp.float32)
        # k-blocked accumulation reassociates adds; tolerance covers that
        tol = 1e-3 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)

    def test_autotuned_matches_ref(self):
        """The no-keyword surface (autotuner resolution) stays exact too."""
        a = jax.random.normal(KEY, (256, 384), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (384, 256), jnp.float32)
        out = gemm(a, b, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(gemm_ref(a, b, jnp.float32)),
                                   rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("swizzle", [
        SwizzleConfig(window=2, chunk=4),
        SwizzleConfig(window=4, chunk=2, enable_chiplet=False)])
    def test_swizzle_invariance(self, swizzle):
        """Grid order must never change the numbers — Algorithm 1 is a pure
        scheduling transform, so every swizzle is BITWISE identical to the
        row-major traversal (same blocks, explicit policies)."""
        a = jax.random.normal(KEY, (512, 256), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (256, 512), jnp.float32)
        base_pol = make_policy("gemm", block_m=128, block_n=128, block_k=128)
        swz_pol = make_policy("gemm", block_m=128, block_n=128, block_k=128,
                              swizzle=swizzle)
        base = gemm(a, b, policy=base_pol, out_dtype=jnp.float32)
        out = gemm(a, b, policy=swz_pol, out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base))

    def test_legacy_swizzle_shim_routes_through_autotuner(self):
        """The swizzle-only legacy surface no longer pins the hard-coded
        pingpong-512 schedule: it ranks the autotuner's candidates under
        the requested traversal order (and still warns). The resolved
        policy's blocks tile the problem exactly — no silent _fit_policy
        clamp for small shapes."""
        m, n, k = 192, 320, 160   # divisor-unfriendly for 512-blocks
        a = jax.random.normal(KEY, (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        sw = SwizzleConfig(window=2, enable_chiplet=False)
        with pytest.warns(DeprecationWarning, match="policy=KernelPolicy"):
            out = gemm(a, b, swizzle=sw, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(gemm_ref(a, b, jnp.float32)),
                                   rtol=1e-3, atol=1e-3)
        pol = autotune.select_policy("gemm", (m, n, k), "float32", swizzle=sw)
        assert pol.swizzle == sw
        assert pol.fits(m, n, k), pol.describe()


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * 0.5
    return x.astype(dtype)


# every epilogue chain shape the model layers use, plus compositions
EPILOGUE_CHAINS = [
    Epilogue(),
    Epilogue(bias=True),
    Epilogue(activation="relu"),
    Epilogue(bias=True, activation="gelu"),
    Epilogue(bias=True, activation="silu", residual=True),
    Epilogue(residual=True, scale=True),           # fused down-proj store
    Epilogue(activation="silu", gate=True),        # dual-output SwiGLU
    Epilogue(activation="gelu", gate=True, residual=True, scale=True),
    Epilogue(rope=True, head_dim=64),              # QKV→RoPE prologue
    Epilogue(bias=True, rope=True, head_dim=64, scale=True),
    Epilogue(scale=True, scale_kind="row"),        # fp8 per-row dequant
    Epilogue(scale=True, scale_kind="col", activation="gelu"),  # per-channel
    Epilogue(scale=True, scale_kind="col", gate=True, activation="silu"),
]

# {fp32, bf16, fp8-scaled} × oracle tolerance. fp8 operands feed the MXU as
# bf16 (exact), but the oracle contracts in fp32 — tolerance covers the
# product rounding; the scale chain is exercised on top for every dtype.
EPILOGUE_DTYPES = [(jnp.float32, 1e-3), (jnp.bfloat16, 3e-2),
                   (jnp.float8_e4m3fn, 6e-2)]


class TestEpilogue:
    """Fused GEMM epilogue/prologue chains vs the unfused jnp oracle."""

    def _operands(self, epilogue, m, n, k, dtype):
        ops = {}
        if epilogue.gate:
            ops["b2"] = _rand(2, (k, n), dtype)
        if epilogue.bias:
            ops["bias"] = _rand(3, (n,), jnp.float32)
        if epilogue.residual:
            ops["residual"] = _rand(4, (m, n), jnp.float32)
        if epilogue.scale:
            if epilogue.scale_kind == "row":
                ops["scale"] = _rand(5, (m, 1), jnp.float32) * 0.1 + 1.0
            elif epilogue.scale_kind == "col":
                ops["scale"] = _rand(5, (n,), jnp.float32) * 0.1 + 1.0
            else:
                ops["scale"] = 0.625
        if epilogue.rope:
            sin, cos = rope_tables(jnp.arange(m), epilogue.head_dim)
            ops["sin"], ops["cos"] = sin, cos
        return ops

    @pytest.mark.parametrize("dtype,tol", EPILOGUE_DTYPES,
                             ids=["fp32", "bf16", "fp8"])
    @pytest.mark.parametrize("ep", EPILOGUE_CHAINS,
                             ids=[e.describe() for e in EPILOGUE_CHAINS])
    def test_chain_matches_oracle(self, ep, dtype, tol):
        m, k, n = 128, 256, 256
        a = _rand(0, (m, k), dtype)
        b = _rand(1, (k, n), dtype)
        ops = self._operands(ep, m, n, k, dtype)
        out = gemm_fused(a, b, epilogue=ep, out_dtype=jnp.float32, **ops)
        ref = gemm_fused_ref(a, b, epilogue=ep, out_dtype=jnp.float32, **ops)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("dtype,tol", EPILOGUE_DTYPES,
                             ids=["fp32", "bf16", "fp8"])
    def test_fp8_style_scaled_dequant(self, dtype, tol):
        """scale epilogue = the fp8 dequant path: out = s·(A@B), with the
        scale applied to BOTH accumulators of the dual-output GEMM."""
        m, k, n = 128, 128, 256
        a = _rand(0, (m, k), dtype)
        b = _rand(1, (k, n), dtype)
        b2 = _rand(2, (k, n), dtype)
        s = 0.125
        ep = Epilogue(activation="silu", gate=True, scale=True)
        out = gemm_fused(a, b, b2=b2, scale=s, epilogue=ep,
                         out_dtype=jnp.float32)
        af, bf, b2f = (x.astype(jnp.float32) for x in (a, b, b2))
        ref = jax.nn.silu(s * (af @ bf)) * (s * (af @ b2f))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)

    def test_swiglu_dual_output_matches_mlp_oracle(self):
        """The dual-output GEMM is exactly the two-up-projection SwiGLU."""
        t, d, f = 128, 256, 384
        x = _rand(0, (t, d), jnp.float32)
        wg = _rand(1, (d, f), jnp.float32)
        wi = _rand(2, (d, f), jnp.float32)
        out = gemm_fused(x, wg, b2=wi,
                         epilogue=Epilogue(activation="silu", gate=True),
                         out_dtype=jnp.float32)
        ref = jax.nn.silu(x @ wg) * (x @ wi)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("head_dim", [64, 128])
    def test_qkv_rope_prologue_matches_oracle(self, head_dim):
        """rope epilogue == project-then-rotate with the rope kernel oracle."""
        s, d, heads = 256, 128, 4
        n = heads * head_dim
        x = _rand(0, (s, d), jnp.float32)
        w = _rand(1, (d, n), jnp.float32)
        sin, cos = rope_tables(jnp.arange(s), head_dim)
        out = gemm_fused(x, w, sin=sin, cos=cos,
                         epilogue=Epilogue(rope=True, head_dim=head_dim),
                         out_dtype=jnp.float32)
        proj = (x @ w).reshape(s, heads, head_dim).transpose(1, 0, 2)[None]
        ref = rope_ref(proj, sin, cos)[0].transpose(1, 0, 2).reshape(s, n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)

    def test_swizzle_invariance_with_epilogue(self):
        """Grid order must never change fused-store numbers either."""
        m = k = n = 256
        a = _rand(0, (m, k), jnp.float32)
        b = _rand(1, (k, n), jnp.float32)
        res = _rand(2, (m, n), jnp.float32)
        ep = Epilogue(activation="gelu", residual=True)
        outs = []
        for window in (1, 2):
            pol = make_policy("gemm", block_m=128, block_n=128, block_k=128,
                              swizzle=SwizzleConfig(window=window,
                                                    enable_chiplet=False),
                              epilogue=ep)
            outs.append(gemm_fused(a, b, residual=res, epilogue=ep,
                                   policy=pol, out_dtype=jnp.float32))
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[1]))

    def test_operand_validation(self):
        a = _rand(0, (128, 128), jnp.float32)
        with pytest.raises(ValueError, match="missing"):
            gemm_fused(a, a, epilogue=Epilogue(bias=True))
        with pytest.raises(ValueError, match="not accepted"):
            gemm_fused(a, a, epilogue=Epilogue(), bias=jnp.zeros(128))
        with pytest.raises(ValueError, match="activation"):
            Epilogue(gate=True)
        with pytest.raises(ValueError, match="head_dim"):
            Epilogue(rope=True, head_dim=0)
        with pytest.raises(ValueError, match="scale_kind"):
            Epilogue(scale_kind="row")          # vector kind needs scale=True
        with pytest.raises(ValueError, match="scale_kind"):
            Epilogue(scale=True, scale_kind="diag")

    def test_vector_scale_vmem_and_traffic_accounting(self):
        """Per-channel scales enter the VMEM legality rule and the traffic
        model as real streamed blocks, not scalars."""
        scalar = Epilogue(scale=True)
        col = Epilogue(scale=True, scale_kind="col")
        row = Epilogue(scale=True, scale_kind="row")
        assert col.scale_block(128, 256) == (1, 256)
        assert row.scale_block(128, 256) == (128, 1)
        m, n = 512, 1024
        assert col.extra_read_bytes(m, n, 2) == n * 4
        assert row.extra_read_bytes(m, n, 2) == m * 4
        assert scalar.extra_read_bytes(m, n, 2) == 4
        base = make_policy("gemm", block_m=256, block_n=256, block_k=256,
                           epilogue=scalar)
        vec = make_policy("gemm", block_m=256, block_n=256, block_k=256,
                          epilogue=col)
        assert vec.vmem_bytes() > base.vmem_bytes()

    def test_epilogue_aware_vmem_legality(self):
        """The gate chain's extra B2 buffers + second accumulator count
        against the VMEM budget: a policy legal without the epilogue can be
        illegal with it."""
        base = make_policy("gemm", block_m=512, block_n=512, block_k=512,
                           n_buffers=3)
        gated = make_policy("gemm", block_m=512, block_n=512, block_k=512,
                            n_buffers=3,
                            epilogue=Epilogue(activation="silu", gate=True))
        assert gated.vmem_bytes() > base.vmem_bytes()
        assert gated.scratch_bytes() == 2 * base.scratch_bytes()

    def test_autotuned_epilogue_policy_carries_chain(self):
        ep = Epilogue(activation="silu", gate=True)
        pol = autotune.select_policy("gemm", (512, 512, 512), "bfloat16",
                                     epilogue=ep)
        assert pol.epilogue == ep
        assert pol.describe()["epilogue"] == "silu*gate"

    def test_plain_gemm_ignores_policy_epilogue(self):
        """The plain op cannot supply epilogue operands: a chain-carrying
        policy contributes its blocks only (no silent relu(A@B))."""
        a = _rand(0, (128, 128), jnp.float32)
        b = _rand(1, (128, 128), jnp.float32)
        pol = autotune.select_policy("gemm", (128, 128, 128), "float32",
                                     epilogue=Epilogue(activation="relu"))
        out = gemm(a, b, policy=pol, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(gemm_ref(a, b, jnp.float32)),
                                   rtol=1e-3, atol=1e-3)

    def test_gemm_fused_rejects_diverging_policy_epilogue(self):
        a = _rand(0, (128, 128), jnp.float32)
        pol = autotune.select_policy("gemm", (128, 128, 128), "float32",
                                     epilogue=Epilogue(activation="relu"))
        with pytest.raises(ValueError, match="carries epilogue"):
            gemm_fused(a, a, epilogue=Epilogue(activation="silu"),
                       policy=pol, out_dtype=jnp.float32)


# every prologue the model layers use: rmsnorm/layernorm × beta, both
# stats paths (recompute pins block_k == K; @rstd streams row stats)
PROLOGUE_CHAINS = [
    Prologue(norm="rmsnorm"),
    Prologue(norm="layernorm"),
    Prologue(norm="layernorm", beta=True),
    Prologue(norm="rmsnorm", precomputed_stats=True),
    Prologue(norm="layernorm", beta=True, precomputed_stats=True),
]

PROLOGUE_DTYPES = [(jnp.float32, 1e-3), (jnp.bfloat16, 3e-2)]


class TestPrologue:
    """Fused norm→GEMM A-tile prologues vs the unfused jnp oracle
    (DESIGN.md §10)."""

    def _operands(self, prologue, a, k):
        ops = {}
        if prologue.norm != "none":
            ops["gamma"] = _rand(30, (k,), jnp.float32) * 0.2 + 1.0
            if prologue.beta:
                ops["beta"] = _rand(31, (k,), jnp.float32) * 0.2
            if prologue.precomputed_stats:
                ops.update(prologue.compute_stats(a))
        return ops

    @pytest.mark.parametrize("dtype,tol", PROLOGUE_DTYPES,
                             ids=["fp32", "bf16"])
    @pytest.mark.parametrize("pro", PROLOGUE_CHAINS,
                             ids=[p.describe() for p in PROLOGUE_CHAINS])
    def test_norm_matches_oracle(self, pro, dtype, tol):
        m, k, n = 128, 256, 256
        a = _rand(0, (m, k), dtype)
        b = _rand(1, (k, n), dtype)
        ops = self._operands(pro, a, k)
        out = gemm_fused(a, b, prologue=pro, out_dtype=jnp.float32, **ops)
        ref = gemm_fused_ref(a, b, prologue=pro, out_dtype=jnp.float32, **ops)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("norm", ["rmsnorm", "layernorm"])
    def test_oracle_matches_standalone_norm(self, norm):
        """The prologue oracle IS norm-then-GEMM: gemm_fused_ref must equal
        models.common.{rmsnorm,layernorm} followed by the plain GEMM (the
        HBM-round-trip chain the prologue eliminates)."""
        from repro.models.common import layernorm, rmsnorm
        m, k, n = 64, 128, 128
        a = _rand(0, (m, k), jnp.float32)
        b = _rand(1, (k, n), jnp.float32)
        gamma = _rand(2, (k,), jnp.float32) * 0.2 + 1.0
        beta = _rand(3, (k,), jnp.float32) * 0.2
        if norm == "rmsnorm":
            pro, ops = Prologue(norm="rmsnorm"), {"gamma": gamma}
            normed = rmsnorm(a, gamma)
        else:
            pro = Prologue(norm="layernorm", beta=True)
            ops = {"gamma": gamma, "beta": beta}
            normed = layernorm(a, gamma, beta)
        out = gemm_fused(a, b, prologue=pro, out_dtype=jnp.float32, **ops)
        ref = normed.astype(jnp.float32) @ b
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)

    def test_fast_path_matches_recompute(self):
        """precomputed-rstd keeps K-blocking: a policy with block_k < K is
        legal on the fast path and matches the full-K recompute (up to
        k-blocked accumulation reassociation)."""
        m, k, n = 128, 512, 256
        a = _rand(0, (m, k), jnp.float32)
        b = _rand(1, (k, n), jnp.float32)
        gamma = _rand(2, (k,), jnp.float32) + 1.0
        full = gemm_fused(a, b, prologue=Prologue(norm="rmsnorm"),
                          gamma=gamma, out_dtype=jnp.float32)
        fast_pro = Prologue(norm="rmsnorm", precomputed_stats=True)
        pol = make_policy("gemm", block_m=128, block_n=128, block_k=128,
                          prologue=fast_pro)
        fast = gemm_fused(a, b, prologue=fast_pro, gamma=gamma,
                          policy=pol, **fast_pro.compute_stats(a),
                          out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(full),
                                   rtol=1e-4, atol=1e-4)

    def test_prologue_epilogue_composed_one_launch(self):
        """Norm prologue + dual-output SwiGLU gate + residual/scale epilogue
        in ONE launch == the full eager pre-norm MLP-up chain."""
        t, d, f = 128, 256, 256
        x = _rand(0, (t, d), jnp.float32)
        wg = _rand(1, (d, f), jnp.float32) * 0.2
        wi = _rand(2, (d, f), jnp.float32) * 0.2
        gamma = _rand(3, (d,), jnp.float32) * 0.2 + 1.0
        from repro.models.common import rmsnorm
        out = gemm_fused(x, wg, b2=wi, prologue=Prologue(norm="rmsnorm"),
                         gamma=gamma,
                         epilogue=Epilogue(activation="silu", gate=True),
                         out_dtype=jnp.float32)
        xn = rmsnorm(x, gamma).astype(jnp.float32)
        ref = jax.nn.silu(xn @ wg) * (xn @ wi)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)

    def test_swizzle_invariance_with_prologue(self):
        """Grid order must never change prologue-fused numbers either."""
        m = k = n = 256
        a = _rand(0, (m, k), jnp.float32)
        b = _rand(1, (k, n), jnp.float32)
        gamma = _rand(2, (k,), jnp.float32) + 1.0
        pro = Prologue(norm="rmsnorm")
        outs = []
        for window in (1, 2):
            pol = make_policy("gemm", block_m=128, block_n=128, block_k=k,
                              swizzle=SwizzleConfig(window=window,
                                                    enable_chiplet=False),
                              prologue=pro)
            outs.append(gemm_fused(a, b, prologue=pro, gamma=gamma,
                                   policy=pol, out_dtype=jnp.float32))
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[1]))

    def test_spec_validation(self):
        a = _rand(0, (128, 128), jnp.float32)
        with pytest.raises(ValueError, match="beta"):
            Prologue(norm="rmsnorm", beta=True)
        with pytest.raises(ValueError, match="unknown norm"):
            Prologue(norm="batchnorm")
        with pytest.raises(ValueError, match="only meaningful"):
            Prologue(beta=True)
        with pytest.raises(ValueError, match="missing"):
            gemm_fused(a, a, prologue=Prologue(norm="rmsnorm"))
        with pytest.raises(ValueError, match="not accepted"):
            gemm_fused(a, a, gamma=jnp.ones(128))
        # the recompute path refuses block_k < K at the spec level...
        with pytest.raises(ValueError, match="full feature dim"):
            Prologue(norm="rmsnorm").check_blocks(64, 128)
        # ...and _fit_policy clamps a small-block policy up to the full K
        # (the clamp-not-raise convention), so the launch still matches
        pol = make_policy("gemm", block_m=128, block_n=128, block_k=64,
                          prologue=Prologue(norm="rmsnorm"))
        gamma = jnp.ones(128)
        out = gemm_fused(a, a, prologue=Prologue(norm="rmsnorm"),
                         gamma=gamma, policy=pol, out_dtype=jnp.float32)
        ref = gemm_fused_ref(a, a, prologue=Prologue(norm="rmsnorm"),
                             gamma=gamma, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)

    def test_prologue_aware_vmem_legality(self):
        """The prologue's gamma/beta rows and stats columns count against
        the VMEM budget, and the autotuned recompute-path policy always
        carries block_k == K."""
        base = make_policy("gemm", block_m=256, block_n=256, block_k=512)
        pro = Prologue(norm="layernorm", beta=True, precomputed_stats=True)
        with_pro = make_policy("gemm", block_m=256, block_n=256, block_k=512,
                               prologue=pro)
        assert with_pro.vmem_bytes() > base.vmem_bytes()
        pol = autotune.select_policy("gemm", (512, 512, 384), "bfloat16",
                                     prologue=Prologue(norm="rmsnorm"))
        assert pol.block_k == 384
        assert pol.prologue == Prologue(norm="rmsnorm")
        assert pol.describe()["prologue"] == "rmsnorm"

    def test_gemm_fused_rejects_diverging_policy_prologue(self):
        a = _rand(0, (128, 128), jnp.float32)
        pol = autotune.select_policy("gemm", (128, 128, 128), "float32",
                                     prologue=Prologue(norm="rmsnorm"))
        with pytest.raises(ValueError, match="carries prologue"):
            gemm_fused(a, a, prologue=Prologue(norm="layernorm"),
                       gamma=jnp.ones(128), policy=pol,
                       out_dtype=jnp.float32)


class TestNormFusionPlan:
    def test_norm_mlp_plan_selected_from_dma_bytes(self):
        """The norm-prologue MLP plan wins on modeled bytes alone, by
        >= 1.3x vs the unfused fused_norm→gemm pair at production shape
        (the ISSUE acceptance bar)."""
        plan = autotune.select_fusion("mlp", (4096, 2048, 8192, True),
                                      prenorm="rmsnorm")
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]
        assert plan["traffic_reduction"] >= 1.3

    def test_norm_plan_beats_plain_plan(self):
        """Folding the norm must strictly increase the modeled saving: the
        prologue removes the norm round trip on top of the epilogue wins."""
        shape = (4096, 2048, 8192, True)
        plain = autotune.select_fusion("mlp", shape)
        normed = autotune.select_fusion("mlp", shape, prenorm="rmsnorm")
        assert normed["traffic_reduction"] > plain["traffic_reduction"]
        # layernorm streams a beta row too: never cheaper than rmsnorm
        ln = autotune.select_fusion("mlp", shape, prenorm="layernorm")
        assert ln["fused_bytes"] >= normed["fused_bytes"]

    def test_norm_qkv_plan(self):
        plan = autotune.select_fusion("qkv_rope", (4096, 2048, 16, 4, 128),
                                      prenorm="rmsnorm")
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]


class TestPrologueModelPaths:
    """Model-layer parity: the norm-fused pre-norm block vs the reference
    chain, incl. grad-parity against the f32 ground truth (f32 params make
    the reference path exact, so it IS the ground truth here)."""

    def _setup(self):
        cfg = types.SimpleNamespace(mlp_act="swiglu", norm="rmsnorm")
        d, f = 256, 512
        x = _rand(0, (2, 64, d), jnp.float32)
        res = _rand(1, (2, 64, d), jnp.float32)
        p = {"w_gate": _rand(2, (d, f), jnp.float32) * 0.1,
             "w_in": _rand(3, (d, f), jnp.float32) * 0.1,
             "w_out": _rand(4, (f, d), jnp.float32) * 0.1,
             "ln_scale": _rand(5, (d,), jnp.float32) * 0.2 + 1.0}
        return cfg, p, x, res

    def test_norm_fused_mlp_block_matches_reference(self):
        from repro.models.common import mlp_forward, norm_params
        cfg, p, x, res = self._setup()
        pn = norm_params(p, "ln")
        ref = mlp_forward(cfg, p, x, mode="reference", residual=res,
                          residual_scale=0.7, prenorm=pn)
        out = mlp_forward(cfg, p, x, mode="pallas_interpret", residual=res,
                          residual_scale=0.7, prenorm=pn)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)

    def test_norm_fused_mlp_grad_parity_f32_truth(self):
        """jax.grad through the norm-prologue megakernel == the f32
        reference gradient (incl. the norm scale's own gradient), via the
        custom VJP that differentiates the unfused oracle."""
        from repro.models.common import mlp_forward, norm_params
        cfg, p, x, res = self._setup()

        def loss(p_, mode):
            return jnp.sum(mlp_forward(cfg, p_, x, mode=mode, residual=res,
                                       residual_scale=0.9,
                                       prenorm=norm_params(p_, "ln")) ** 2)

        g_truth = jax.grad(lambda p_: loss(p_, "reference"))(p)
        g_fused = jax.grad(lambda p_: loss(p_, "pallas_interpret"))(p)
        for key in p:
            np.testing.assert_allclose(np.asarray(g_fused[key]),
                                       np.asarray(g_truth[key]),
                                       rtol=2e-3, atol=2e-3, err_msg=key)

    def test_norm_fused_attention_layer_matches_reference(self):
        from repro.models.attention import (attention_layer,
                                            fused_project_qkv_rope)
        h, hkv, hd, d = 4, 2, 64, 256
        cfg = types.SimpleNamespace(num_heads=h, num_kv_heads=hkv,
                                    head_dim=hd, d_model=d, qkv_bias=False,
                                    rope_style="half", rope_theta=10000.0,
                                    norm="rmsnorm")
        b, s = 2, 128
        x = _rand(0, (b, s, d), jnp.float32)
        p = {"wqk": _rand(1, (d, (h + hkv) * hd), jnp.float32) * 0.1,
             "wv": _rand(2, (d, hkv * hd), jnp.float32) * 0.1,
             "wo": _rand(3, (h * hd, d), jnp.float32) * 0.1}
        pn = (_rand(4, (d,), jnp.float32) * 0.2 + 1.0, None)
        # the norm-fused prologue actually engages for this config
        assert fused_project_qkv_rope(cfg, p, x, jnp.arange(s),
                                      "pallas_interpret",
                                      prenorm=pn) is not None
        ref = attention_layer(cfg, p, x, causal=True, mode="reference",
                              prenorm=pn)
        out = attention_layer(cfg, p, x, causal=True,
                              mode="pallas_interpret", prenorm=pn)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4)


class TestFitPolicyClamp:
    """_fit_policy clamps to the largest divisor block instead of raising."""

    @pytest.mark.parametrize("m,n,k", [(192, 320, 160), (300, 200, 100),
                                       (128, 384, 1280)])
    def test_non_divisible_problems_clamp(self, m, n, k):
        a = _rand(0, (m, k), jnp.float32)
        b = _rand(1, (k, n), jnp.float32)
        pol = make_policy("gemm", block_m=512, block_n=512, block_k=512)
        out = gemm(a, b, policy=pol, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(gemm_ref(a, b, jnp.float32)),
                                   rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("shape", [(192, 320, 160), (384, 640, 256),
                                       (1536, 1024, 768)])
    def test_autotuner_candidates_fit(self, shape):
        """The autotuner never emits a candidate whose blocks would have
        needed the clamp (divisibility is part of candidate legality)."""
        sig = autotune.OpSignature("gemm", shape)
        cands = autotune.candidate_policies(sig)
        assert cands
        for pol in cands:
            assert pol.fits(*shape), (pol.describe(), shape)


class TestFusionPlan:
    def test_mlp_plan_selected_from_dma_bytes(self):
        """The fused MLP plan wins on modeled bytes alone, by >= 1.5x at
        production shape (the ISSUE acceptance bar)."""
        plan = autotune.select_fusion("mlp", (4096, 2048, 8192, True))
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]
        assert plan["traffic_reduction"] >= 1.5

    def test_qkv_plan_selected_from_dma_bytes(self):
        plan = autotune.select_fusion("qkv_rope", (4096, 2048, 16, 4, 128))
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]

    def test_no_hardcoded_preference(self):
        """The decision really comes from the byte model: when the chain
        saves ~nothing (tiny token count vs huge weights), the margin
        collapses — the plan field is always derived from the same
        numbers, never from a flag."""
        plan = autotune.select_fusion("mlp", (8, 4096, 16384, True))
        assert plan["traffic_reduction"] < 1.05
        # and the plan field is derived from the same numbers
        expect = ("fused" if plan["fused_bytes"] < plan["unfused_bytes"]
                  else "unfused")
        assert plan["plan"] == expect

    def test_qkv_packed_weights_win_at_small_tokens(self):
        """[wq|wk] is pre-packed at param-build time, so the fused qkv plan
        no longer pays a token-independent in-graph concat: it strictly
        removes passes and wins even at tiny token counts (the case the
        concat used to lose) — still decided from the byte model, whose
        margin collapses toward 1 as the weights dominate."""
        plan = autotune.select_fusion("qkv_rope", (64, 4096, 32, 8, 128))
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]
        assert plan["traffic_reduction"] < 1.1  # weight-dominated margin

    def test_moe_expert_plan_has_no_residual_term(self):
        """The expert FFN chain carries no residual add: its plan must be
        scored without the phantom residual traffic."""
        with_res = autotune.select_fusion("mlp", (256, 512, 1024, True),
                                          residual=True)
        without = autotune.select_fusion("mlp", (256, 512, 1024, True),
                                         residual=False)
        assert without["unfused_bytes"] < with_res["unfused_bytes"]
        assert without["traffic_reduction"] < with_res["traffic_reduction"]


class TestFusedModelPaths:
    """Model-layer parity: fused megakernel paths vs the reference chains."""

    def test_mlp_forward_fused_matches_reference(self):
        cfg = types.SimpleNamespace(mlp_act="swiglu")
        d, f = 256, 512
        x = _rand(0, (2, 64, d), jnp.float32)
        res = _rand(1, (2, 64, d), jnp.float32)
        p = {"w_gate": _rand(2, (d, f), jnp.float32) * 0.1,
             "w_in": _rand(3, (d, f), jnp.float32) * 0.1,
             "w_out": _rand(4, (f, d), jnp.float32) * 0.1}
        from repro.models.common import mlp_forward
        ref = mlp_forward(cfg, p, x, mode="reference", residual=res,
                          residual_scale=0.7)
        out = mlp_forward(cfg, p, x, mode="pallas_interpret", residual=res,
                          residual_scale=0.7)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("qkv_bias", [False, True])
    def test_attention_layer_fused_qkv_rope_matches_reference(self, qkv_bias):
        from repro.models.attention import (attention_layer,
                                            fused_project_qkv_rope)
        h, hkv, hd, d = 4, 2, 64, 256
        cfg = types.SimpleNamespace(num_heads=h, num_kv_heads=hkv,
                                    head_dim=hd, d_model=d, qkv_bias=qkv_bias,
                                    rope_style="half", rope_theta=10000.0)
        b, s = 2, 128
        x = _rand(0, (b, s, d), jnp.float32)
        p = {"wqk": _rand(1, (d, (h + hkv) * hd), jnp.float32) * 0.1,
             "wv": _rand(3, (d, hkv * hd), jnp.float32) * 0.1,
             "wo": _rand(4, (h * hd, d), jnp.float32) * 0.1}
        if qkv_bias:
            p.update(bqk=_rand(5, ((h + hkv) * hd,), jnp.float32) * 0.1,
                     bv=_rand(7, (hkv * hd,), jnp.float32) * 0.1)
        # the fused prologue actually engages for this config
        assert fused_project_qkv_rope(cfg, p, x, jnp.arange(s),
                                      "pallas_interpret") is not None
        ref = attention_layer(cfg, p, x, causal=True, mode="reference")
        out = attention_layer(cfg, p, x, causal=True, mode="pallas_interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4)

    def test_fused_mlp_grad_matches_reference(self):
        """gemm_fused's custom VJP (autodiff of the unfused oracle) keeps
        the fused MLP trainable with reference-exact gradients."""
        from repro.models.common import mlp_forward
        cfg = types.SimpleNamespace(mlp_act="swiglu")
        d, f = 128, 256
        x = _rand(0, (1, 64, d), jnp.float32)
        res = _rand(1, (1, 64, d), jnp.float32)
        p = {"w_gate": _rand(2, (d, f), jnp.float32) * 0.2,
             "w_in": _rand(3, (d, f), jnp.float32) * 0.2,
             "w_out": _rand(4, (f, d), jnp.float32) * 0.2}

        def loss(p, mode):
            return jnp.sum(mlp_forward(cfg, p, x, mode=mode, residual=res,
                                       residual_scale=0.9) ** 2)

        g_ref = jax.grad(lambda p_: loss(p_, "reference"))(p)
        g_fus = jax.grad(lambda p_: loss(p_, "pallas_interpret"))(p)
        for key in p:
            np.testing.assert_allclose(np.asarray(g_fus[key]),
                                       np.asarray(g_ref[key]),
                                       rtol=2e-3, atol=2e-3)

    def test_moe_dense_fused_matches_reference(self):
        from repro.models.moe import moe_dense
        cfg = types.SimpleNamespace(
            mlp_act="swiglu",
            moe=types.SimpleNamespace(num_experts=4, top_k=2,
                                      capacity_factor=1.25, impl="dense",
                                      shard="expert"))
        d, f = 128, 256
        x = _rand(0, (1, 32, d), jnp.float32)
        p = {"router": _rand(1, (d, 4), jnp.float32) * 0.1,
             "w_in": _rand(2, (4, d, f), jnp.float32) * 0.1,
             "w_gate": _rand(3, (4, d, f), jnp.float32) * 0.1,
             "w_out": _rand(4, (4, f, d), jnp.float32) * 0.1}
        o_ref, aux_ref = moe_dense(cfg, p, x, mode="reference")
        o_fus, aux_fus = moe_dense(cfg, p, x, mode="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o_fus), np.asarray(o_ref),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(aux_fus), np.asarray(aux_ref),
                                   rtol=1e-6, atol=1e-6)


class TestAttention:
    @pytest.mark.parametrize("h,hkv", [(2, 2), (4, 1), (8, 2)])
    @pytest.mark.parametrize("d", [64, 128])
    @pytest.mark.parametrize("causal", [False, True])
    def test_fwd_matches_ref(self, h, hkv, d, causal):
        b, s = 2, 256
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, hkv, s, d))
        v = jax.random.normal(ks[2], (b, hkv, s, d))
        out, _ = flash_attention_fwd(q, k, v, causal=causal)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [64, 128, 1000])
    def test_sliding_window(self, window):
        b, h, s, d = 1, 2, 384, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        out, _ = flash_attention_fwd(q, k, v, causal=True, window=window)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("h,hkv,causal,window", [
        (2, 2, False, None), (4, 2, True, None), (4, 1, True, 128)])
    def test_bwd_matches_autodiff(self, h, hkv, causal, window):
        b, s, d = 1, 256, 64
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, hkv, s, d))
        v = jax.random.normal(ks[2], (b, hkv, s, d))
        do = jax.random.normal(ks[3], (b, h, s, d))

        def f_kernel(q, k, v):
            return (attention(q, k, v, causal=causal, window=window) * do).sum()

        def f_ref(q, k, v):
            return (attention(q, k, v, causal=causal, window=window,
                              mode="reference") * do).sum()

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-3, atol=1e-3)

    def test_bf16_inputs(self):
        b, h, s, d = 1, 2, 256, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, h, s, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, h, s, d), jnp.bfloat16)
        out, _ = flash_attention_fwd(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_chunked_ref_matches_direct(self):
        b, h, s, d = 1, 4, 512, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        o1 = attention_ref(q, k, v, causal=True)
        o2 = attention_ref_chunked(q, k, v, causal=True, chunk=128)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-5, atol=1e-5)

    @given(sq=st.sampled_from([128, 256]), skv=st.sampled_from([128, 256, 384]))
    @settings(max_examples=10, deadline=None)
    def test_cross_lengths(self, sq, skv):
        """Property: works for Sq != Skv (cross-attention shapes)."""
        b, h, d = 1, 2, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, sq, d))
        k = jax.random.normal(ks[1], (b, h, skv, d))
        v = jax.random.normal(ks[2], (b, h, skv, d))
        out, _ = flash_attention_fwd(q, k, v, causal=False)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestFusedNorm:
    @pytest.mark.parametrize("rows,d", [(256, 128), (512, 1024), (128, 768)])
    @pytest.mark.parametrize("p", [0.0, 0.1, 0.5])
    def test_matches_ref(self, rows, d, p):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (rows, d))
        r = jax.random.normal(ks[1], (rows, d))
        w = jax.random.normal(ks[2], (d,))
        b = jax.random.normal(ks[3], (d,))
        o1, r1 = dropout_residual_layernorm(x, r, w, b, 7, dropout_p=p)
        o2, r2 = fused_dropout_residual_layernorm_ref(x, r, w, b, 7,
                                                      dropout_p=p)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)

    def test_normalization_property(self):
        """Output rows (pre-affine) have mean≈0, var≈1."""
        x = jax.random.normal(KEY, (64, 512))
        r = jnp.zeros((64, 512))
        o, _ = dropout_residual_layernorm(x, r, jnp.ones(512), jnp.zeros(512))
        of = np.asarray(o, np.float64)
        np.testing.assert_allclose(of.mean(1), 0, atol=1e-4)
        np.testing.assert_allclose(of.var(1), 1, atol=1e-2)

    @given(p=st.floats(0.05, 0.9), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_dropout_rate_property(self, p, seed):
        """Keep rate ≈ 1-p, and the mask is deterministic in the seed."""
        mask1 = dropout_keep_mask_ref(seed, (256, 512), p)
        mask2 = dropout_keep_mask_ref(seed, (256, 512), p)
        assert (np.asarray(mask1) == np.asarray(mask2)).all()
        rate = float(np.asarray(mask1).mean())
        assert abs(rate - (1 - p)) < 0.02

    def test_dropout_scaling_preserves_mean(self):
        x = jnp.ones((512, 512))
        r = jnp.zeros((512, 512))
        _, resid = dropout_residual_layernorm(x, r, jnp.ones(512),
                                              jnp.zeros(512), 3, dropout_p=0.3)
        assert abs(float(jnp.mean(resid)) - 1.0) < 0.05


class TestRope:
    @pytest.mark.parametrize("b,h,s,d", [(2, 4, 256, 128), (1, 2, 512, 64)])
    def test_matches_ref(self, b, h, s, d):
        x = jax.random.normal(KEY, (b, h, s, d))
        sin, cos = rope_tables(jnp.arange(s), d)
        np.testing.assert_allclose(np.asarray(rope(x, sin, cos)),
                                   np.asarray(rope_ref(x, sin, cos)),
                                   atol=1e-5)

    def test_norm_preservation_property(self):
        """Rotation preserves the norm of each (x_i, x_{i+d/2}) pair."""
        x = jax.random.normal(KEY, (1, 1, 256, 64))
        sin, cos = rope_tables(jnp.arange(256), 64)
        y = np.asarray(rope(x, sin, cos), np.float64)
        xn = np.asarray(x, np.float64)
        n_in = xn[..., :32] ** 2 + xn[..., 32:] ** 2
        n_out = y[..., :32] ** 2 + y[..., 32:] ** 2
        np.testing.assert_allclose(n_in, n_out, atol=1e-5)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n (the RoPE guarantee)."""
        d = 64
        q = jax.random.normal(KEY, (1, 1, 1, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
        def dot_at(m, n):
            sin_m, cos_m = rope_tables(jnp.asarray([m]), d)
            sin_n, cos_n = rope_tables(jnp.asarray([n]), d)
            qm = rope_ref(q, sin_m, cos_m)
            kn = rope_ref(k, sin_n, cos_n)
            return float(jnp.sum(qm * kn))
        assert abs(dot_at(5, 3) - dot_at(102, 100)) < 1e-4
        assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-4
