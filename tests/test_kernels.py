"""Per-kernel correctness: shape/dtype sweeps, Pallas (interpret) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.grid_swizzle import SwizzleConfig
from repro.core.schedule import Schedule
from repro.kernels.gemm import gemm, gemm_ref
from repro.kernels.attention import (attention, attention_ref,
                                     flash_attention_fwd)
from repro.kernels.attention.ref import attention_ref_chunked
from repro.kernels.fused_norm import (dropout_residual_layernorm,
                                      fused_dropout_residual_layernorm_ref)
from repro.kernels.fused_norm.ref import dropout_keep_mask_ref
from repro.kernels.rope import rope, rope_ref, rope_tables

KEY = jax.random.PRNGKey(0)


class TestGemm:
    @pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 512, 384),
                                       (512, 256, 1280), (384, 384, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, n, k, dtype):
        a = jax.random.normal(KEY, (m, k), dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
        s = Schedule("t", 2, 256, 256, 256)
        out = gemm(a, b, schedule=s, out_dtype=jnp.float32)
        ref = gemm_ref(a, b, jnp.float32)
        # k-blocked accumulation reassociates adds; tolerance covers that
        tol = 1e-3 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("swizzle", [
        SwizzleConfig(window=2, chunk=4),
        SwizzleConfig(window=4, chunk=2, enable_chiplet=False), "auto"])
    def test_swizzle_invariance(self, swizzle):
        """Grid order must never change the numbers — Algorithm 1 is a pure
        scheduling transform, so every swizzle is BITWISE identical to the
        row-major traversal."""
        a = jax.random.normal(KEY, (512, 256), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (256, 512), jnp.float32)
        s = Schedule("t", 2, 128, 128, 128)
        base = gemm(a, b, schedule=s, swizzle=None, out_dtype=jnp.float32)
        out = gemm(a, b, schedule=s, swizzle=swizzle, out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


class TestAttention:
    @pytest.mark.parametrize("h,hkv", [(2, 2), (4, 1), (8, 2)])
    @pytest.mark.parametrize("d", [64, 128])
    @pytest.mark.parametrize("causal", [False, True])
    def test_fwd_matches_ref(self, h, hkv, d, causal):
        b, s = 2, 256
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, hkv, s, d))
        v = jax.random.normal(ks[2], (b, hkv, s, d))
        out, _ = flash_attention_fwd(q, k, v, causal=causal)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [64, 128, 1000])
    def test_sliding_window(self, window):
        b, h, s, d = 1, 2, 384, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        out, _ = flash_attention_fwd(q, k, v, causal=True, window=window)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("h,hkv,causal,window", [
        (2, 2, False, None), (4, 2, True, None), (4, 1, True, 128)])
    def test_bwd_matches_autodiff(self, h, hkv, causal, window):
        b, s, d = 1, 256, 64
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, hkv, s, d))
        v = jax.random.normal(ks[2], (b, hkv, s, d))
        do = jax.random.normal(ks[3], (b, h, s, d))

        def f_kernel(q, k, v):
            return (attention(q, k, v, causal=causal, window=window) * do).sum()

        def f_ref(q, k, v):
            return (attention(q, k, v, causal=causal, window=window,
                              mode="reference") * do).sum()

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-3, atol=1e-3)

    def test_bf16_inputs(self):
        b, h, s, d = 1, 2, 256, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, h, s, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, h, s, d), jnp.bfloat16)
        out, _ = flash_attention_fwd(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_chunked_ref_matches_direct(self):
        b, h, s, d = 1, 4, 512, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        o1 = attention_ref(q, k, v, causal=True)
        o2 = attention_ref_chunked(q, k, v, causal=True, chunk=128)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-5, atol=1e-5)

    @given(sq=st.sampled_from([128, 256]), skv=st.sampled_from([128, 256, 384]))
    @settings(max_examples=10, deadline=None)
    def test_cross_lengths(self, sq, skv):
        """Property: works for Sq != Skv (cross-attention shapes)."""
        b, h, d = 1, 2, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, sq, d))
        k = jax.random.normal(ks[1], (b, h, skv, d))
        v = jax.random.normal(ks[2], (b, h, skv, d))
        out, _ = flash_attention_fwd(q, k, v, causal=False)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestFusedNorm:
    @pytest.mark.parametrize("rows,d", [(256, 128), (512, 1024), (128, 768)])
    @pytest.mark.parametrize("p", [0.0, 0.1, 0.5])
    def test_matches_ref(self, rows, d, p):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (rows, d))
        r = jax.random.normal(ks[1], (rows, d))
        w = jax.random.normal(ks[2], (d,))
        b = jax.random.normal(ks[3], (d,))
        o1, r1 = dropout_residual_layernorm(x, r, w, b, 7, dropout_p=p)
        o2, r2 = fused_dropout_residual_layernorm_ref(x, r, w, b, 7,
                                                      dropout_p=p)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)

    def test_normalization_property(self):
        """Output rows (pre-affine) have mean≈0, var≈1."""
        x = jax.random.normal(KEY, (64, 512))
        r = jnp.zeros((64, 512))
        o, _ = dropout_residual_layernorm(x, r, jnp.ones(512), jnp.zeros(512))
        of = np.asarray(o, np.float64)
        np.testing.assert_allclose(of.mean(1), 0, atol=1e-4)
        np.testing.assert_allclose(of.var(1), 1, atol=1e-2)

    @given(p=st.floats(0.05, 0.9), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_dropout_rate_property(self, p, seed):
        """Keep rate ≈ 1-p, and the mask is deterministic in the seed."""
        mask1 = dropout_keep_mask_ref(seed, (256, 512), p)
        mask2 = dropout_keep_mask_ref(seed, (256, 512), p)
        assert (np.asarray(mask1) == np.asarray(mask2)).all()
        rate = float(np.asarray(mask1).mean())
        assert abs(rate - (1 - p)) < 0.02

    def test_dropout_scaling_preserves_mean(self):
        x = jnp.ones((512, 512))
        r = jnp.zeros((512, 512))
        _, resid = dropout_residual_layernorm(x, r, jnp.ones(512),
                                              jnp.zeros(512), 3, dropout_p=0.3)
        assert abs(float(jnp.mean(resid)) - 1.0) < 0.05


class TestRope:
    @pytest.mark.parametrize("b,h,s,d", [(2, 4, 256, 128), (1, 2, 512, 64)])
    def test_matches_ref(self, b, h, s, d):
        x = jax.random.normal(KEY, (b, h, s, d))
        sin, cos = rope_tables(jnp.arange(s), d)
        np.testing.assert_allclose(np.asarray(rope(x, sin, cos)),
                                   np.asarray(rope_ref(x, sin, cos)),
                                   atol=1e-5)

    def test_norm_preservation_property(self):
        """Rotation preserves the norm of each (x_i, x_{i+d/2}) pair."""
        x = jax.random.normal(KEY, (1, 1, 256, 64))
        sin, cos = rope_tables(jnp.arange(256), 64)
        y = np.asarray(rope(x, sin, cos), np.float64)
        xn = np.asarray(x, np.float64)
        n_in = xn[..., :32] ** 2 + xn[..., 32:] ** 2
        n_out = y[..., :32] ** 2 + y[..., 32:] ** 2
        np.testing.assert_allclose(n_in, n_out, atol=1e-5)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n (the RoPE guarantee)."""
        d = 64
        q = jax.random.normal(KEY, (1, 1, 1, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
        def dot_at(m, n):
            sin_m, cos_m = rope_tables(jnp.asarray([m]), d)
            sin_n, cos_n = rope_tables(jnp.asarray([n]), d)
            qm = rope_ref(q, sin_m, cos_m)
            kn = rope_ref(k, sin_n, cos_n)
            return float(jnp.sum(qm * kn))
        assert abs(dot_at(5, 3) - dot_at(102, 100)) < 1e-4
        assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-4
