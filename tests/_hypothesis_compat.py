"""Optional-``hypothesis`` shim for the test suite.

``hypothesis`` is an optional dev dependency (see requirements-dev.txt).
When it is installed, this module re-exports the real ``given``/``settings``/
``strategies``. When the import fails, it provides a minimal deterministic
fallback: each ``@given(...)`` test runs against a fixed table of cases drawn
from the strategies with a seeded RNG — no shrinking, no property search,
but the same test body executes and the suite collects and passes without
the dependency.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by which path imports
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10  # fixed case-table size per @given test

    class _Strategy:
        """A draw()-able stand-in for a hypothesis strategy."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

    st = _Strategies()

    def settings(**kwargs):
        """Accepted and ignored (max_examples/deadline are hypothesis-only);
        the fallback always runs its fixed case table."""

        def deco(fn):
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        """Run the test body over a deterministic fixed case table.

        Cases are drawn with an RNG seeded from the test name, so failures
        reproduce run-to-run and are independent of test order.
        """

        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(f"repro:{fn.__module__}.{fn.__qualname__}")
                for _ in range(_FALLBACK_EXAMPLES):
                    drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {name: s.draw(rng)
                                for name, s in kw_strategies.items()}
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)

            # NOT functools.wraps: copying __wrapped__ would make pytest
            # introspect fn's signature and demand fixtures for the drawn
            # parameters. The opaque (*args, **kwargs) signature is the point.
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            return wrapper

        return deco
