"""First-class docs are tested docs: link integrity + quickstart syntax.

The CI docs-check step additionally *executes* the README quickstart
(tools/docs_check.py); here the cheap half runs under tier-1 so a broken
link or syntax error in a code sample never lands.
"""
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import docs_check  # noqa: E402


def test_required_docs_exist():
    for name in ("README.md", "DESIGN.md", "ROADMAP.md",
                 "docs/fusion-authoring.md"):
        assert (REPO / name).exists(), name


def test_intra_repo_links_resolve():
    assert docs_check.check_links() == 0


def test_readme_quickstart_blocks_compile():
    blocks = docs_check.quickstart_blocks(REPO / "README.md")
    assert blocks, "README.md must carry a runnable ```python quickstart"
    for i, block in enumerate(blocks):
        compile(block, f"README.md#block{i + 1}", "exec")


@pytest.mark.parametrize("doc,section", [
    ("DESIGN.md", "## §9"),
    ("DESIGN.md", "## §10"),
    ("docs/fusion-authoring.md", "norm"),
])
def test_doc_sections_present(doc, section):
    assert section in (REPO / doc).read_text(), (doc, section)
