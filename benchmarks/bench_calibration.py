"""Calibration drift bench: measured-vs-analytic ranking agreement.

Runs the interpret-path calibration sweep (docs/autotuning.md) and emits
one row per op family plus the fitted chip coefficients:

  * ``calib_sweep``      — wall-clock of the whole calibrate() run;
    derived carries cell/candidate counts and the drift-gate verdict.
  * ``calib_<family>``   — per-op-family top-1 agreement and mean Spearman
    rank correlation between analytic and measured candidate rankings
    (the same numbers tools/drift_check.py gates CI on).
  * ``calib_fitted_chip``— the least-squares-recovered ChipSpec
    coefficients, as ratios to the analytic V5E defaults.

``$BENCH_SMOKE`` selects the CI-sized sweep.
"""
from __future__ import annotations

import os

from repro.core import calibrate as cal
from repro.core import perf_model as pm
from .common import emit, measure_cell


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    out: dict = {}

    def run():
        out["report"] = cal.calibrate(smoke=smoke, arch="cpu")

    us = measure_cell(run, warmup=0, iters=1)["us"]
    report = out["report"]
    drift = cal.check_drift(report)
    n_cands = sum(len(c["candidates"]) for c in report["cells"].values())
    emit("calib_sweep", us,
         f"cells={len(report['cells'])};candidates={n_cands};"
         f"fusion={len(report['fusion'])};"
         f"drift={'ok' if drift['ok'] else 'VIOLATED'}")
    for op, fam in sorted(drift["families"].items()):
        emit(f"calib_{op}", us / max(1, drift["n_cells"]) * fam["cells"],
             f"top1={fam['top1_agreement']:.2f};"
             f"spearman={fam['mean_spearman']:.3f};cells={fam['cells']}")
    chip = report["chip"]
    emit("calib_fitted_chip", 0.0,
         f"flops_ratio={chip['peak_flops_bf16'] / pm.V5E.peak_flops_bf16:.3f};"
         f"bw_ratio={chip['hbm_bw'] / pm.V5E.hbm_bw:.3f};"
         f"step_us={chip['step_overhead_s'] * 1e6:.2f};"
         f"decode_ramp={chip['decode_saturation_steps']}")


if __name__ == "__main__":
    main()
