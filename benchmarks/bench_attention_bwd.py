"""Paper Fig. 8/15: attention backward (GQA + MHA, causal/non-causal).

Derived: modeled v5e TFLOP/s for the two-pass flash backward (dq + dkv ≈
2.5x forward FLOPs); measured: grad of the reference path at scaled shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core import perf_model as pm
from repro.kernels.attention import attention
from .common import measure_cell, emit


def main() -> None:
    for name, h, hkv in (("mha", 16, 16), ("gqa", 64, 8)):
        for seq in (2048, 4096, 8192, 16384):
            for causal in (False, True):
                fwd = pm.attention_step_model(
                    block_q=128, block_kv=128, head_dim=128, seq_len=seq,
                    causal=causal, dtype_bytes=2)
                # flash bwd: dq pass + dkv pass, each ~fwd compute + extra dp
                modeled = fwd["modeled_tflops"] * (5.0 / 2.0) / 2.9
                tag = f"attn_bwd_{name}_s{seq}_{'causal' if causal else 'full'}"
                b_s, s_s, d = 1, min(seq, 512), 128
                ks = jax.random.split(jax.random.PRNGKey(0), 3)
                q = jax.random.normal(ks[0], (b_s, 4, s_s, d))
                k = jax.random.normal(ks[1], (b_s, 2, s_s, d))
                v = jax.random.normal(ks[2], k.shape)
                fn = jax.jit(jax.grad(lambda q, k, v: attention(
                    q, k, v, causal=causal, mode="reference").sum(),
                    argnums=(0, 1, 2)))
                us = measure_cell(fn, q, k, v, warmup=2, iters=5)["us"]
                # fused flash backward vs recompute+materialized-scores
                # chain, planned from modeled dma_bytes (DESIGN.md §12)
                plan = autotune.select_fusion(
                    "attention", (16, h, hkv, seq, seq, 128), "bfloat16",
                    causal=causal, backward=True)
                emit(tag, us, f"modeled_tflops={modeled:.0f};"
                     f"bound={fwd['bound']};plan={plan['plan']};"
                     f"traffic_reduction={plan['traffic_reduction']:.2f}")


if __name__ == "__main__":
    main()
