"""Fused-MLP megakernel sweep (DESIGN.md §9-§10; paper Fig. 9 regime).

seq × d_model sweep of the transformer MLP hot chain: modeled HBM traffic of
the fused plan (dual-output SwiGLU up-GEMM + residual-fused down-GEMM) vs
the unfused eager chain, with the plan the autotuner picks from
``dma_bytes`` alone (``autotune.select_fusion`` — no hard-coded
preference). Each cell also carries the *norm-fused* column: the same chain
with the block's pre-norm folded into the up-GEMM's A-tile prologue,
scored against the unfused ``fused_norm``→``gemm`` pair (the standalone
norm pass + eager chain). Rows land in ``BENCH_fused_mlp.json`` via
benchmarks.run; the acceptance bars are ``traffic_reduction >= 1.5`` and
``norm_traffic_reduction >= 1.3`` on every production-shaped cell.

Also validates the fused interpret-mode kernels end to end on a small MLP
(vs the unfused jnp oracle, with and without the norm prologue) and times
the two jnp chains on CPU for scale.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.models.common import mlp_forward, norm_params
from .common import time_fn, emit


class _MlpCfg:
    mlp_act = "swiglu"
    norm = "rmsnorm"


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    # seq = tokens per launch (batch × seq of a train/prefill step): at
    # production token counts the activation round trips dominate the
    # (fixed) weight traffic, which is where fusion pays (paper Fig. 9).
    seqs = (2048, 8192) if smoke else (2048, 8192, 32768)
    dims = (1024, 2048) if smoke else (1024, 2048, 4096)
    for seq in seqs:
        for d in dims:
            f = 4 * d
            plan = autotune.select_fusion("mlp", (seq, d, f, True))
            norm_plan = autotune.select_fusion("mlp", (seq, d, f, True),
                                               prenorm="rmsnorm")
            emit(f"fused_mlp_s{seq}_d{d}", 0.0,
                 f"plan={plan['plan']};"
                 f"fused_mb={plan['fused_bytes'] / 2**20:.1f};"
                 f"unfused_mb={plan['unfused_bytes'] / 2**20:.1f};"
                 f"traffic_reduction={plan['traffic_reduction']:.2f}x;"
                 f"norm_plan={norm_plan['plan']};"
                 f"norm_fused_mb={norm_plan['fused_bytes'] / 2**20:.1f};"
                 f"norm_unfused_mb={norm_plan['unfused_bytes'] / 2**20:.1f};"
                 f"norm_traffic_reduction="
                 f"{norm_plan['traffic_reduction']:.2f}x;"
                 f"modeled_fused_us={plan['fused']['time_s'] * 1e6:.1f};"
                 f"modeled_unfused_us={plan['unfused']['time_s'] * 1e6:.1f};"
                 f"bound={plan['fused']['bound']}")

    # end-to-end parity + CPU timing on a small MLP: the fused dual-GEMM +
    # residual-epilogue path (interpret mode) vs the unfused jnp oracle
    cfg = _MlpCfg()
    t, d, f = 256, 512, 1024
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (1, t, d), jnp.float32) * 0.5
    res = jax.random.normal(ks[1], (1, t, d), jnp.float32)
    p = {"w_gate": jax.random.normal(ks[2], (d, f), jnp.float32) * 0.05,
         "w_in": jax.random.normal(ks[3], (d, f), jnp.float32) * 0.05,
         "w_out": jax.random.normal(ks[4], (f, d), jnp.float32) * 0.05}
    ref_fn = jax.jit(lambda x, res: mlp_forward(
        cfg, p, x, mode="reference", residual=res, residual_scale=0.5))
    us_ref = time_fn(ref_fn, x, res)
    out = mlp_forward(cfg, p, x, mode="pallas_interpret", residual=res,
                      residual_scale=0.5)
    err = float(jnp.abs(out - ref_fn(x, res)).max())
    assert err < 1e-3, err
    emit(f"fused_mlp_pallas_check_t{t}_d{d}", us_ref,
         f"max_err={err:.2e};plan="
         f"{autotune.select_fusion('mlp', (t, d, f, True))['plan']}")

    # norm-prologue path: the whole pre-norm block (norm → dual-GEMM →
    # residual) in two launches, vs the standalone-norm reference chain
    p["ln_scale"] = jax.random.normal(ks[5], (d,), jnp.float32) * 0.1 + 1.0
    pn = norm_params(p, "ln")
    norm_ref_fn = jax.jit(lambda x, res: mlp_forward(
        cfg, p, x, mode="reference", residual=res, residual_scale=0.5,
        prenorm=pn))
    us_norm_ref = time_fn(norm_ref_fn, x, res)
    out = mlp_forward(cfg, p, x, mode="pallas_interpret", residual=res,
                      residual_scale=0.5, prenorm=pn)
    err = float(jnp.abs(out - norm_ref_fn(x, res)).max())
    assert err < 1e-3, err
    emit(f"norm_fused_mlp_pallas_check_t{t}_d{d}", us_norm_ref,
         f"max_err={err:.2e};norm_plan="
         f"{autotune.select_fusion('mlp', (t, d, f, True), prenorm='rmsnorm')['plan']}")


if __name__ == "__main__":
    main()
