"""Fused-MLP megakernel sweep (DESIGN.md §9-§11; paper Fig. 9 regime).

seq × d_model sweep of the transformer MLP hot chain: modeled HBM traffic of
the fused plan (dual-output SwiGLU up-GEMM + residual-fused down-GEMM) vs
the unfused eager chain, with the plan the autotuner picks from
``dma_bytes`` alone (``autotune.select_fusion`` — no hard-coded
preference). Each cell also carries the *norm-fused* column (the same chain
with the block's pre-norm folded into the up-GEMM's A-tile prologue,
scored against the unfused ``fused_norm``→``gemm`` pair) and the *bwd*
columns: the kernel-side fused backward — saved-preact streams + two fused
bwd GEMM launches per fwd GEMM, norm transposed tile-wise (DESIGN.md §11)
— vs the oracle-recompute VJP, from the same byte models
(``select_fusion(backward=True)``). Rows land in ``BENCH_fused_mlp.json``
via benchmarks.run; the acceptance bars are ``traffic_reduction >= 1.5``,
``norm_traffic_reduction >= 1.3``, and ``bwd_traffic_reduction`` /
``norm_bwd_traffic_reduction >= 1.3`` on every train-shaped cell.

Also validates the fused interpret-mode kernels end to end on a small MLP
(vs the unfused jnp oracle, with and without the norm prologue), checks
jax.grad parity of the kernel-side fused backward against the oracle VJP
on the same MLP, and times the two jnp chains on CPU for scale.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.kernels.gemm import default_bwd_mode
from repro.models.common import mlp_forward, norm_params
from .common import measure_cell, emit


class _MlpCfg:
    mlp_act = "swiglu"
    norm = "rmsnorm"


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    # seq = tokens per launch (batch × seq of a train/prefill step): at
    # production token counts the activation round trips dominate the
    # (fixed) weight traffic, which is where fusion pays (paper Fig. 9).
    seqs = (2048, 8192) if smoke else (2048, 8192, 32768)
    dims = (1024, 2048) if smoke else (1024, 2048, 4096)
    for seq in seqs:
        for d in dims:
            f = 4 * d
            plan = autotune.select_fusion("mlp", (seq, d, f, True))
            norm_plan = autotune.select_fusion("mlp", (seq, d, f, True),
                                               prenorm="rmsnorm")
            bwd = autotune.select_fusion("mlp", (seq, d, f, True),
                                         backward=True)
            norm_bwd = autotune.select_fusion("mlp", (seq, d, f, True),
                                              backward=True,
                                              prenorm="rmsnorm")
            emit(f"fused_mlp_s{seq}_d{d}", 0.0,
                 f"plan={plan['plan']};"
                 f"fused_mb={plan['fused_bytes'] / 2**20:.1f};"
                 f"unfused_mb={plan['unfused_bytes'] / 2**20:.1f};"
                 f"traffic_reduction={plan['traffic_reduction']:.2f}x;"
                 f"norm_plan={norm_plan['plan']};"
                 f"norm_fused_mb={norm_plan['fused_bytes'] / 2**20:.1f};"
                 f"norm_unfused_mb={norm_plan['unfused_bytes'] / 2**20:.1f};"
                 f"norm_traffic_reduction="
                 f"{norm_plan['traffic_reduction']:.2f}x;"
                 f"bwd_plan={bwd['plan']};"
                 f"bwd_fused_mb={bwd['fused_bytes'] / 2**20:.1f};"
                 f"bwd_oracle_mb={bwd['unfused_bytes'] / 2**20:.1f};"
                 f"bwd_traffic_reduction={bwd['traffic_reduction']:.2f}x;"
                 f"norm_bwd_traffic_reduction="
                 f"{norm_bwd['traffic_reduction']:.2f}x;"
                 f"modeled_fused_us={plan['fused']['time_s'] * 1e6:.1f};"
                 f"modeled_unfused_us={plan['unfused']['time_s'] * 1e6:.1f};"
                 f"bound={plan['fused']['bound']}")

    # end-to-end parity + CPU timing on a small MLP: the fused dual-GEMM +
    # residual-epilogue path (interpret mode) vs the unfused jnp oracle
    cfg = _MlpCfg()
    t, d, f = 256, 512, 1024
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (1, t, d), jnp.float32) * 0.5
    res = jax.random.normal(ks[1], (1, t, d), jnp.float32)
    p = {"w_gate": jax.random.normal(ks[2], (d, f), jnp.float32) * 0.05,
         "w_in": jax.random.normal(ks[3], (d, f), jnp.float32) * 0.05,
         "w_out": jax.random.normal(ks[4], (f, d), jnp.float32) * 0.05}
    ref_fn = jax.jit(lambda x, res: mlp_forward(
        cfg, p, x, mode="reference", residual=res, residual_scale=0.5))
    us_ref = measure_cell(ref_fn, x, res)["us"]
    out = mlp_forward(cfg, p, x, mode="pallas_interpret", residual=res,
                      residual_scale=0.5)
    err = float(jnp.abs(out - ref_fn(x, res)).max())
    assert err < 1e-3, err
    emit(f"fused_mlp_pallas_check_t{t}_d{d}", us_ref,
         f"max_err={err:.2e};plan="
         f"{autotune.select_fusion('mlp', (t, d, f, True))['plan']}")

    # norm-prologue path: the whole pre-norm block (norm → dual-GEMM →
    # residual) in two launches, vs the standalone-norm reference chain
    p["ln_scale"] = jax.random.normal(ks[5], (d,), jnp.float32) * 0.1 + 1.0
    pn = norm_params(p, "ln")
    norm_ref_fn = jax.jit(lambda x, res: mlp_forward(
        cfg, p, x, mode="reference", residual=res, residual_scale=0.5,
        prenorm=pn))
    us_norm_ref = measure_cell(norm_ref_fn, x, res)["us"]
    out = mlp_forward(cfg, p, x, mode="pallas_interpret", residual=res,
                      residual_scale=0.5, prenorm=pn)
    err = float(jnp.abs(out - norm_ref_fn(x, res)).max())
    assert err < 1e-3, err
    emit(f"norm_fused_mlp_pallas_check_t{t}_d{d}", us_norm_ref,
         f"max_err={err:.2e};norm_plan="
         f"{autotune.select_fusion('mlp', (t, d, f, True), prenorm='rmsnorm')['plan']}")

    # kernel-side fused backward (DESIGN.md §11): jax.grad through the same
    # pre-norm MLP on the default (kernel) bwd path vs the oracle VJP
    def loss(p_, bwd):
        with default_bwd_mode(bwd):
            return jnp.sum(mlp_forward(cfg, p_, x, mode="pallas_interpret",
                                       residual=res, residual_scale=0.5,
                                       prenorm=norm_params(p_, "ln")) ** 2)

    g_kern = jax.grad(lambda p_: loss(p_, "kernel"))(p)
    g_orac = jax.grad(lambda p_: loss(p_, "reference"))(p)
    gerr = max(float(jnp.abs(g_kern[k] - g_orac[k]).max()) for k in p)
    assert gerr < 1e-2, gerr
    bwd_plan = autotune.select_fusion("mlp", (t, d, f, True), backward=True,
                                      prenorm="rmsnorm")
    emit(f"fused_mlp_bwd_check_t{t}_d{d}", 0.0,
         f"max_grad_err={gerr:.2e};bwd_plan={bwd_plan['plan']};"
         f"bwd_traffic_reduction={bwd_plan['traffic_reduction']:.2f}x")


if __name__ == "__main__":
    main()
