"""Paper Tab. 4 / Figs. 5, 18: chiplet-aware grid scheduling.

Two levels (DESIGN.md §2):
  1. the cache simulator reproduces the paper's L2/LLC hit-rate trade-off for
     row-major vs Algorithm-1 schedules on the MI355X-like hierarchy
     (including the paper's coprime-width worst case, 57 tiles x 8 XCDs);
  2. the Pallas-revisit DMA model scores the same schedules by real
     HBM→VMEM traffic on TPU, and we *measure* that the swizzled kernel is
     numerically identical (pure scheduling transform).
"""
from __future__ import annotations

from repro.core.cache_model import CacheHW, simulate_gemm_schedule
from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR, dma_bytes
from .common import emit


def main() -> None:
    # --- paper Tab. 4, 9216 case (MT 192x256x64) ---
    cases = [("row-major", ROW_MAJOR),
             ("xcd_w7_c216", SwizzleConfig(window=7, chunk=216)),
             ("xcd_w5_c25", SwizzleConfig(window=5, chunk=25))]
    for m in (9216, 14592):
        for name, cfg in cases:
            r = simulate_gemm_schedule(cfg, m=m, n=m, k=m, block_m=192,
                                       block_n=256, block_k=64)
            emit(f"tab4_{m}_{name}", 0.0,
                 f"l2={r.l2_hit:.0%};llc={r.llc_hit:.0%};"
                 f"bw_tbs={r.effective_bw / 1e12:.1f};"
                 f"modeled_tflops={r.modeled_tflops:.0f}")

    # coprime worst case: 57 tiles across 8 XCDs (paper §3.4)
    m = 57 * 256
    for name, cfg in cases:
        r = simulate_gemm_schedule(cfg, m=m, n=m, k=4096, block_m=256,
                                   block_n=256, block_k=64)
        emit(f"tab4_coprime57_{name}", 0.0,
             f"l2={r.l2_hit:.0%};llc={r.llc_hit:.0%};"
             f"bw_tbs={r.effective_bw / 1e12:.1f}")

    # --- TPU single-core level: Pallas-revisit DMA traffic ---
    nb = 16
    a_b = 512 * 8192 * 2  # full-K A block bytes (512x512 tiles, K=8192)
    for name, cfg in (("row_major_runs", ROW_MAJOR),
                      ("window4", SwizzleConfig(window=4, enable_chiplet=False)),
                      ("column_runs", SwizzleConfig(window=nb,
                                                    enable_chiplet=False))):
        traffic = dma_bytes(cfg, nb, nb, a_b, a_b)
        emit(f"tpu_dma_{name}", 0.0,
             f"hbm_gib={traffic / 2**30:.1f};"
             f"vs_min={traffic / ((nb + nb * nb) * a_b):.2f}x")


if __name__ == "__main__":
    main()
