"""Shared benchmark utilities.

Each benchmark prints ``name,us_per_call,derived`` CSV rows.
``us_per_call`` is a real wall-clock measurement of the XLA-CPU reference
path (interpret-mode Pallas timings are not meaningful); ``derived`` carries
the modeled TPU-v5e number that reproduces the paper's table/figure
(TFLOP/s, hit-rates, bandwidths) — this container has no TPU, so modeled
numbers are the deliverable per the roofline methodology.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro import obs

# When non-None, emit() also appends structured rows here (benchmarks.run
# uses this to write machine-readable BENCH_<key>.json artifacts next to
# the CSV stream, so the perf trajectory is diffable across commits).
_CAPTURE: list | None = None
# Telemetry capture bracketing the same window: begin_capture() opens an
# obs.capture(), end_capture() closes it and parks the recorder so
# write_bench_json() can embed the summary + export the trace files.
_OBS_CM = None
_LAST_REC: obs.Recorder | None = None


def begin_capture() -> None:
    global _CAPTURE, _OBS_CM, _LAST_REC
    _CAPTURE = []
    _OBS_CM = obs.capture()
    _LAST_REC = _OBS_CM.__enter__()


def end_capture() -> list:
    global _CAPTURE, _OBS_CM
    rows, _CAPTURE = _CAPTURE or [], None
    if _OBS_CM is not None:
        _OBS_CM.__exit__(None, None, None)
        _OBS_CM = None
    return rows


def last_recorder() -> obs.Recorder | None:
    """The telemetry recorder from the most recent capture window."""
    return _LAST_REC


def parse_derived(derived: str) -> dict:
    """'k=v;k2=v2' -> {k: float-or-str}; bare tokens keep their string."""
    out = {}
    for part in str(derived).split(";"):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            key, val = part.split("=", 1)
            try:
                out[key] = float(val.rstrip("x%"))
            except ValueError:
                out[key] = val
        else:
            out[part] = True
    return out


def write_bench_json(key: str, rows: list, out_dir: str | None = None) -> str:
    """Write BENCH_<key>.json (dir from $BENCH_OUT, default cwd).

    When a telemetry capture bracketed the bench (begin/end_capture), the
    journal summary is embedded as a ``telemetry`` block and the full trace
    is exported beside it as TRACE_<key>.json (Chrome-trace/Perfetto) and
    COUNTERS_<key>.json (flat counters + launch counts).
    """
    out_dir = out_dir or os.environ.get("BENCH_OUT", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{key}.json")
    payload = {"bench": key, "rows": rows}
    rec = _LAST_REC
    if rec is not None:
        payload["telemetry"] = rec.summary()
        obs.export_chrome_trace(rec, os.path.join(out_dir,
                                                  f"TRACE_{key}.json"))
        obs.export_counters(rec, os.path.join(out_dir,
                                              f"COUNTERS_{key}.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


def measure_cell(fn, *args, warmup: int = 3, iters: int = 10) -> dict:
    """Measure one bench cell: wall-clock stats of ``fn(*args)``.

    The single timing loop every bench module shares — tests enforce that
    no bench module keeps a stray ``time.perf_counter`` loop of its own,
    so methodology changes (trimming, counter bracketing) land everywhere
    at once. ``warmup=0, iters=1`` is the one-shot path for side-effectful
    cells (e.g. an engine run that consumes its queue).

    Returns ``{"us": median microseconds, "seconds": median seconds,
    "min_us": best iteration, "iters": iters}``.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    return {"us": med * 1e6, "seconds": med, "min_us": times[0] * 1e6,
            "iters": len(times)}


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
    if _CAPTURE is not None:
        _CAPTURE.append({"name": name, "us_per_call": round(us, 1),
                         "derived": str(derived),
                         "derived_parsed": parse_derived(derived)})


def gemm_candidate_sweep(shape: tuple):
    """The autotuner's GEMM candidate set for ``shape`` = (m, n, k), deduped
    by (block_m, block_n, block_k, n_buffers) — the swizzle axis moves DMA
    traffic, not the step model's TFLOPs. Yields (policy, selected: bool).
    Shared by bench_gemm and bench_schedules so their tables agree."""
    from repro.core import autotune

    sig = autotune.OpSignature("gemm", shape)
    chosen = autotune.select_policy("gemm", shape)
    chosen_key = (chosen.block_m, chosen.block_n, chosen.block_k,
                  chosen.n_buffers)
    seen = set()
    for pol in autotune.candidate_policies(sig):
        key = (pol.block_m, pol.block_n, pol.block_k, pol.n_buffers)
        if key in seen:
            continue
        seen.add(key)
        if key == chosen_key:
            # report the actually-selected policy (its swizzle included),
            # not whichever swizzle variant happened to come first
            yield chosen, True
        else:
            yield pol, False
