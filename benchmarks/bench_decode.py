"""Paper Fig. 9-style memory-bound decode sweep: split-KV kernel vs einsum.

Sweeps seq_len × batch × GQA ratio at q_len = 1 — the regime where the
paper's wins are largest (1.2-2.4×, memory-bound + GQA). Per DESIGN.md §7:
``us_per_call`` measures the jitted einsum reference decode on XLA-CPU
(scale only); ``derived`` carries the modeled v5e numbers — the split-KV
policy the autotuner picks, its achieved-bandwidth fraction, and the
modeled speedup over a no-split launch (one grid cell per (batch, kv_head),
which under-occupies the DMA pipeline exactly when batch × kv_heads is
small — the split-KV story). A paged-layout row shows the page-granular
split's overhead vs the tuned contiguous split.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core import perf_model as pm
from repro.kernels.attention import attention_decode
from .common import measure_cell, emit


def _modeled(b, hkv, group, skv, d, block_kv):
    return pm.decode_step_model(batch=b, kv_heads=hkv, group=group,
                                kv_len=skv, head_dim=d, block_kv=block_kv)


def _row(name, b, h, hkv, skv, d, *, page_size=None):
    group = h // hkv
    rng = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(rng[0], (b, h, 1, d), jnp.float32)
    k = jax.random.normal(rng[1], (b, hkv, skv, d), jnp.float32)
    v = jax.random.normal(rng[2], (b, hkv, skv, d), jnp.float32)
    lengths = jnp.full((b,), skv, jnp.int32)

    fn = jax.jit(lambda q, k, v: attention_decode(q, k, v, lengths,
                                                  mode="reference"))
    us = measure_cell(fn, q, k, v)["us"]

    if page_size is None:
        pol = autotune.select_policy("attention_decode",
                                     (b, hkv, group, skv, d))
        block_kv = pol.block_kv
    else:
        block_kv = page_size
    tuned = _modeled(b, hkv, group, skv, d, block_kv)
    nosplit = _modeled(b, hkv, group, skv, d, skv)
    emit(name, us,
         f"modeled_v5e_us={tuned['time_s'] * 1e6:.1f};"
         f"block_kv={block_kv};n_splits={tuned['n_splits']};"
         f"bw_frac={tuned['achieved_bw'] / pm.V5E.hbm_bw:.2f};"
         f"split_speedup={nosplit['time_s'] / tuned['time_s']:.2f}x")


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    if smoke:
        seqs, batches, groups, h, d = (128, 256), (1, 2), (1, 4), 4, 16
    else:
        seqs, batches, groups, h, d = (512, 2048, 4096), (1, 4), (1, 8), 8, 64
    for skv in seqs:
        for b in batches:
            for group in groups:
                hkv = h // group
                _row(f"decode_s{skv}_b{b}_g{group}", b, h, hkv, skv, d)
    # paged layout: split size pinned to the physical page
    skv, b, group = seqs[-1], batches[0], groups[-1]
    page = 64 if smoke else 256
    _row(f"decode_paged_s{skv}_b{b}_g{group}_p{page}", b, h, h // group,
         skv, d, page_size=page)


if __name__ == "__main__":
    main()
