"""Paper Fig. 6 / Fig. 14: GEMM throughput across square sizes.

Derived column: modeled v5e TFLOP/s from the pipeline model (per schedule) +
the measured XLA-CPU reference time for scale. Also validates the Pallas
kernel once per size (interpret) so the benchmark exercises the real code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import PINGPONG, INTERLEAVE
from repro.core import perf_model as pm
from repro.kernels.gemm import gemm, gemm_ref
from .common import time_fn, emit


SIZES = (1024, 2048, 4096, 8192)


def main() -> None:
    for n in SIZES:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.bfloat16)
        b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.bfloat16)
        ref = jax.jit(lambda a, b: gemm_ref(a, b))
        us = time_fn(ref, a, b)
        for sched in (PINGPONG, INTERLEAVE):
            m = pm.gemm_step_model(sched, k_total=n)
            emit(f"gemm_bf16_{n}x{n}x{n}_{sched.name}", us,
                 f"modeled_tflops={m['modeled_tflops']:.0f};"
                 f"bound={m['bound']};ai={m['arithmetic_intensity']:.0f}")
    # correctness spot-check through the Pallas kernel (small size)
    n = 512
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.bfloat16)
    out = gemm(a, b, out_dtype=jnp.float32)
    ref = gemm_ref(a, b, jnp.float32)
    err = float(jnp.abs(out - ref).max())
    assert err < 0.5, err
    emit("gemm_pallas_interpret_check_512", 0.0, f"max_err={err:.2e}")


if __name__ == "__main__":
    main()
