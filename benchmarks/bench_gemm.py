"""Paper Fig. 6 / Fig. 14: GEMM throughput across square sizes.

Derived column: modeled v5e TFLOP/s per candidate KernelPolicy from the
autotuner's candidate set (replacing the old private PINGPONG/INTERLEAVE
pair) + the measured XLA-CPU reference time for scale. The autotuner's
selected policy is marked ``selected=yes``. Also validates the Pallas
kernel once per size (interpret) so the benchmark exercises the real code.

The epilogue sweep (DESIGN.md §9) adds per-chain fused-vs-unfused modeled
HBM bytes from ``perf_model.gemm_epilogue_model`` and a fused-store
correctness check through the real kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core import perf_model as pm
from repro.kernels.gemm import gemm, gemm_ref
from .common import measure_cell, emit, gemm_candidate_sweep


SIZES = (1024, 2048, 4096, 8192)

# epilogue sweep cells: chain name -> gemm_epilogue_model flags
EPILOGUE_SWEEP = (
    ("bias", dict(bias=True)),
    ("bias_gelu", dict(bias=True, activation=True)),
    ("swiglu_dual", dict(gate=True, activation=True)),
    ("residual", dict(residual=True)),
    ("bias_act_residual", dict(bias=True, activation=True, residual=True)),
)


def main() -> None:
    for n in SIZES:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.bfloat16)
        b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.bfloat16)
        ref = jax.jit(lambda a, b: gemm_ref(a, b))
        us = measure_cell(ref, a, b)["us"]
        for pol, selected in gemm_candidate_sweep((n, n, n)):
            m = pm.gemm_step_model(pol.schedule, k_total=n)
            emit(f"gemm_bf16_{n}x{n}x{n}_b{pol.block_m}x{pol.block_n}"
                 f"x{pol.block_k}x{pol.n_buffers}", us,
                 f"modeled_tflops={m['modeled_tflops']:.0f};"
                 f"bound={m['bound']};ai={m['arithmetic_intensity']:.0f};"
                 f"selected={'yes' if selected else 'no'}")
    # epilogue sweep (DESIGN.md §9): modeled HBM bytes of GEMM + chain, the
    # fused megakernel vs the eager per-op sequence
    n = 2048
    for name, kw in EPILOGUE_SWEEP:
        f_m = pm.gemm_epilogue_model(m=n, n=n, k=n, fused=True, **kw)
        u_m = pm.gemm_epilogue_model(m=n, n=n, k=n, fused=False, **kw)
        emit(f"gemm_epilogue_{name}_{n}", 0.0,
             f"fused_mb={f_m['dma_bytes'] / 2**20:.1f};"
             f"unfused_mb={u_m['dma_bytes'] / 2**20:.1f};"
             f"traffic_reduction={u_m['dma_bytes'] / f_m['dma_bytes']:.2f}x;"
             f"bound={f_m['bound']}")

    # correctness spot-check through the Pallas kernel (small size), using
    # the autotuner-selected policy end to end
    n = 512
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.bfloat16)
    out = gemm(a, b, out_dtype=jnp.float32)
    ref = gemm_ref(a, b, jnp.float32)
    err = float(jnp.abs(out - ref).max())
    assert err < 0.5, err
    pol = autotune.select_policy("gemm", (n, n, n), str(a.dtype))
    emit("gemm_pallas_interpret_check_512", 0.0,
         f"max_err={err:.2e};policy={pol.describe()['blocks']}")

    # and once through the fused epilogue store (bias + gelu + residual)
    from repro.kernels.gemm import Epilogue, gemm_fused, gemm_fused_ref
    ep = Epilogue(bias=True, activation="gelu", residual=True)
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    bias = jax.random.normal(ks[0], (n,), jnp.float32)
    resid = jax.random.normal(ks[1], (n, n), jnp.float32)
    out = gemm_fused(a, b, epilogue=ep, bias=bias, residual=resid,
                     out_dtype=jnp.float32)
    ref = gemm_fused_ref(a, b, epilogue=ep, bias=bias, residual=resid,
                         out_dtype=jnp.float32)
    err = float(jnp.abs(out - ref).max())
    assert err < 0.5, err
    emit("gemm_epilogue_pallas_interpret_check_512", 0.0,
         f"max_err={err:.2e};epilogue={ep.describe()}")


if __name__ == "__main__":
    main()
