"""Distributed fused-execution sweep (DESIGN.md §16).

Modeled plan decisions for the sharded hot chains, from the same byte
models ``select_fusion`` ranks with — no hard-coded preference:

* MoE train cells: the per-rank expert MLP chain under EP (all_to_all
  dispatch) and TP (all_reduce epilogue), fused vs unfused with the
  interconnect term riding both plans. The acceptance bars (CI-asserted
  from ``BENCH_distributed.json``) are ``plan == fused`` and
  ``traffic_reduction >= 1.2`` on every train cell.
* Ring collective-GEMM cells: ring-overlapped vs gather-then-GEMM for the
  two Megatron TP collectives on train shapes. Bars: ``plan == fused``
  and ``overlap_fraction > 0`` on every cell.
* The sequence-parallel KV term: the partial-softmax all-reduce a decode
  step pays when ``cache_specs`` shards the KV sequence dim over 'model'.

``us_per_call`` is 0.0 throughout — these are modeled-TPU rows (the
container has no TPU; DESIGN.md §7), the same convention as
``bench_fused_mlp``.
"""
from __future__ import annotations

import os

from repro.core import autotune
from repro.core import perf_model as pm
from repro.distributed.sharding import ShardSpec
from .common import emit


def _spec(n_shards: int, dim: str, collective: str) -> ShardSpec:
    return ShardSpec(mesh=(("model", n_shards),),
                     partition=((dim, "model"),), collective=collective)


def _moe_cells(smoke: bool):
    # (label, tokens, d_model, d_ff, n_shards, dim, collective)
    # EP keeps the full d_ff per expert; TP shards d_ff |model|-ways.
    cells = [
        ("moe_ep_s4096_d2048", 4096, 2048, 8192, 4, "expert", "all_to_all"),
        ("moe_tp_s4096_d2048", 4096, 2048, 8192 // 4, 4, "ffn", "all_reduce"),
    ]
    if not smoke:
        cells += [
            ("moe_ep_s8192_d4096", 8192, 4096, 16384, 8, "expert",
             "all_to_all"),
            ("moe_tp_s8192_d4096", 8192, 4096, 16384 // 8, 8, "ffn",
             "all_reduce"),
        ]
    return cells


def _ring_cells(smoke: bool):
    # (label, m, n, k, n_shards, collective)
    cells = [
        ("ring_ag_4096", 4096, 4096, 4096, 4, "all_gather"),
        ("ring_rs_4096", 4096, 4096, 4096, 4, "reduce_scatter"),
    ]
    if not smoke:
        cells += [
            ("ring_ag_8192", 8192, 8192, 8192, 8, "all_gather"),
            ("ring_rs_8192", 8192, 8192, 8192, 8, "reduce_scatter"),
        ]
    return cells


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))

    for label, seq, d, f, ns, dim, coll in _moe_cells(smoke):
        shard = _spec(ns, dim, coll)
        plan = autotune.select_fusion("mlp", (seq, d, f, 1), "bfloat16",
                                      residual=False, shard=shard)
        emit(label, 0.0,
             f"plan={plan['plan']};"
             f"shards={ns};collective={coll};"
             f"fused_mb={plan['fused_bytes'] / 2**20:.1f};"
             f"unfused_mb={plan['unfused_bytes'] / 2**20:.1f};"
             f"traffic_reduction={plan['traffic_reduction']:.2f}x;"
             f"collective_mb={plan['collective_bytes'] / 2**20:.1f};"
             f"overlap_fraction={plan['overlap_fraction']:.3f}")

    for label, m, n, k, ns, coll in _ring_cells(smoke):
        shard = _spec(ns, "rows" if coll == "all_gather" else "contract",
                      coll)
        plan = autotune.select_fusion("gemm_collective", (m, n, k),
                                      "bfloat16", shard=shard)
        chosen = plan["fused"] if plan["plan"] == "fused" else plan["unfused"]
        emit(label, 0.0,
             f"plan={plan['plan']};"
             f"shards={ns};collective={coll};"
             f"ring_steps={chosen.get('ring_steps', 1)};"
             f"fused_mb={plan['fused_bytes'] / 2**20:.1f};"
             f"unfused_mb={plan['unfused_bytes'] / 2**20:.1f};"
             f"traffic_reduction={plan['traffic_reduction']:.2f}x;"
             f"collective_mb={plan['collective_bytes'] / 2**20:.1f};"
             f"overlap_fraction={plan['overlap_fraction']:.3f}")

    # sequence-parallel KV decode: the tiny all-reduce the partial softmax
    # pays for a |model|-fold KV-memory cut (cache_specs)
    for batch, heads, hd, ns in ((8, 32, 128, 4),):
        rows = batch * heads
        coll = pm.partial_softmax_allreduce_model(rows=rows, head_dim=hd,
                                                  n_shards=ns)
        emit(f"seqpar_kv_b{batch}_h{heads}", 0.0,
             f"shards={ns};wire_kb={coll['wire_bytes'] / 1024:.1f};"
             f"collective_us={coll['collective_s'] * 1e6:.2f};"
             f"steps={coll['steps']}")


if __name__ == "__main__":
    main()
