"""Serving fast-path benchmark: prefix cache, chunked prefill, speculation.

vLLM-class throughput features over the paged engine (DESIGN.md §14), on
the smoke LM with measured XLA-CPU wall clock for scale and modeled-v5e
numbers as the deliverable (DESIGN.md §7):

  * ``serve_slots_b{N}`` — end-to-end tokens/s as active slots grow (the
    continuous-batching curve).
  * ``serve_prefix_warm`` — every request repeats one system prompt; after
    a priming run the trie serves the shared pages, so ``hit_rate`` is 1.0
    and ``prefill_traffic_reduction`` is the modeled cold/warm GEMM-work
    ratio (the CI floor is 2x).
  * ``serve_chunked`` — fixed-size chunks interleave with decode;
    ``stall_frac`` is the modeled worst decode-step stall (one chunk) as a
    fraction of one full-prompt prefill — bounded below 1.0 by
    construction.
  * ``serve_spec_selfdraft`` — draft == target, so every proposal verifies
    and ``mean_tokens_per_round`` == spec_tokens; the modeled
    ``verify_speedup`` is the serial-vs-verify KV-stream ratio.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.configs import get_config
from repro.core import perf_model as pm
from repro.models.api import build_model
from repro.serve.engine import PagedEngine, Request
from .common import emit, measure_cell

# modeled-v5e shape for the derived columns (an 8B-class GQA LM; the smoke
# LM only provides the measured XLA-CPU scale)
MODELED = dict(d_model=4096, n_layers=32, num_heads=32, kv_heads=8,
               head_dim=128, d_ff=12800)


def _build():
    cfg = get_config("granite-8b", smoke=True)
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run(eng, reqs) -> float:
    """Submit + run to idle; returns wall seconds."""
    for r in reqs:
        eng.submit(r)
    # one-shot: the run consumes the queue, so no warmup/repeat
    return measure_cell(eng.run, warmup=0, iters=1)["seconds"]


def _reqs(cfg, n, plen, max_new, *, prefix=None, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for uid in range(n):
        tail_len = plen - (len(prefix) if prefix is not None else 0)
        tail = rng.integers(0, cfg.vocab_size, tail_len).astype(np.int32)
        prompt = (np.concatenate([prefix, tail]) if prefix is not None
                  else tail)
        out.append(Request(uid, prompt, max_new))
    return out


def _throughput(cfg, model, params, slots, n_req, plen, max_new):
    eng = PagedEngine(model, params, batch_slots=slots, page_size=8,
                      max_pages_per_seq=8)
    wall = _run(eng, _reqs(cfg, n_req, plen, max_new))
    rep = eng.report()
    dec = pm.decode_step_model(batch=slots, kv_heads=MODELED["kv_heads"],
                               group=MODELED["num_heads"]
                               // MODELED["kv_heads"],
                               kv_len=plen + max_new,
                               head_dim=MODELED["head_dim"], block_kv=256)
    modeled_tps = slots / (dec["time_s"] * MODELED["n_layers"])
    emit(f"serve_slots_b{slots}", wall * 1e6,
         f"tokens_per_s={rep['tokens_generated'] / wall:.1f};"
         f"modeled_v5e_tokens_per_s={modeled_tps:.0f};"
         f"steps={rep['steps']};admissions={rep['admissions']}")


def _prefix_cell(cfg, model, params, plen, suffix, max_new):
    sys_prompt = np.arange(1, plen - suffix + 1, dtype=np.int32) \
        % cfg.vocab_size
    eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                      max_pages_per_seq=8, n_pages=64, prefix_cache=True)
    # priming run populates the trie; the measured cell is all warm
    _run(eng, _reqs(cfg, 1, plen, 2, prefix=sys_prompt, seed=1))
    eng.prefix.lookups = eng.prefix.hits = eng.prefix.matched_tokens = 0
    wall = _run(eng, _reqs(cfg, 4, plen, max_new, prefix=sys_prompt, seed=2))
    rep = eng.report()["prefix_cache"]
    cold = pm.serve_prefill_model(tokens=1024, total_tokens=1024, **MODELED)
    warm = pm.serve_prefill_model(
        tokens=1024 * suffix // plen, total_tokens=1024, **MODELED)
    emit("serve_prefix_warm", wall * 1e6,
         f"hit_rate={rep['hit_rate']:.2f};"
         f"matched_tokens={rep['matched_tokens']};"
         f"pages_held={rep['pages_held']};"
         f"prefill_traffic_reduction="
         f"{cold['gemm_flops'] / warm['gemm_flops']:.2f}x")


def _chunked_cell(cfg, model, params, plen, chunk, max_new):
    eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                      max_pages_per_seq=8, chunk_tokens=chunk)
    wall = _run(eng, _reqs(cfg, 3, plen, max_new, seed=3))
    rep = eng.report()["chunked_prefill"]
    full = pm.serve_prefill_model(tokens=1024, total_tokens=1024, **MODELED)
    one = pm.serve_prefill_model(tokens=1024 * chunk // plen,
                                 total_tokens=1024, **MODELED)
    emit("serve_chunked", wall * 1e6,
         f"chunk_tokens={chunk};chunks={rep['chunks']};"
         f"modeled_stall_us={one['time_s'] * 1e6:.1f};"
         f"modeled_full_prefill_us={full['time_s'] * 1e6:.1f};"
         f"stall_frac={one['time_s'] / full['time_s']:.3f}")


def _spec_cell(cfg, model, params, plen, k, max_new):
    eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                      max_pages_per_seq=8, draft_model=model,
                      draft_params=params, spec_tokens=k)
    wall = _run(eng, _reqs(cfg, 3, plen, max_new, seed=4))
    rep = eng.report()["speculative"]
    sv = pm.spec_verify_model(batch=2, kv_heads=MODELED["kv_heads"],
                              group=MODELED["num_heads"]
                              // MODELED["kv_heads"],
                              kv_len=4096, head_dim=MODELED["head_dim"],
                              block_kv=256, q_tokens=k,
                              mean_accepted=rep["mean_tokens_per_round"])
    emit("serve_spec_selfdraft", wall * 1e6,
         f"k={k};rounds={rep['rounds']};"
         f"accept_rate={rep['accept_rate']:.2f};"
         f"mean_tokens_per_round={rep['mean_tokens_per_round']:.2f};"
         f"modeled_verify_speedup={sv['speedup_vs_serial']:.2f}x")


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    cfg, model, params = _build()
    if smoke:
        slot_counts, n_req, plen, max_new, chunk, k = (1, 2), 3, 24, 4, 8, 3
    else:
        slot_counts, n_req, plen, max_new, chunk, k = (1, 2, 4), 6, 48, 8, 16, 4
    for slots in slot_counts:
        _throughput(cfg, model, params, slots, n_req, plen, max_new)
    _prefix_cell(cfg, model, params, plen, suffix=8, max_new=max_new)
    _chunked_cell(cfg, model, params, plen, chunk, max_new)
    _spec_cell(cfg, model, params, plen, k, max_new)


if __name__ == "__main__":
    main()
