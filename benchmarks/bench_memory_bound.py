"""Paper Fig. 9: memory-bound kernels — fused dropout-residual-layernorm and
RoPE (batch 16, heads 16, head dim 128 per the paper).

Derived: achievable bandwidth fraction on v5e. The fused kernel moves exactly
2 reads + 2 writes of the activation; the unfused chain moves 7 activation
passes — the fusion factor is the paper's win. Modeled bytes come from
``perf_model.dropout_residual_ln_traffic`` / ``perf_model.rope_traffic``
(the same accounting the autotuner's fusion-plan selection uses), reproduced
here three ways: measured CPU time (fused jnp vs unfused jnp), modeled v5e
time (bytes / 819 GB/s), and the real Pallas kernel in interpret mode
(validated against the jnp oracle; interpret wall-time is not meaningful).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import perf_model as pm
from repro.kernels.fused_norm import (dropout_residual_layernorm,
                                      fused_dropout_residual_layernorm_ref)
from repro.kernels.fused_norm.ref import dropout_keep_mask_ref
from repro.kernels.rope import rope, rope_ref, rope_tables
from .common import measure_cell, emit


def unfused(x, r, w, b, seed, p):
    """The torch-eager equivalent: separate dropout, add, layernorm."""
    keep = dropout_keep_mask_ref(seed, x.shape, p)          # mask materialized
    xd = jnp.where(keep, x / (1 - p), 0.0)
    resid = r + xd
    mean = jnp.mean(resid, axis=1, keepdims=True)
    var = jnp.var(resid, axis=1, keepdims=True)
    out = (resid - mean) * jax.lax.rsqrt(var + 1e-5) * w + b
    return out, resid


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    d = 2048  # 16 heads x 128
    seqs = (2048,) if smoke else (2048, 4096, 8192)
    hbm_bw = pm.V5E.hbm_bw
    for seq in seqs:
        rows = seq
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (rows, d))
        r = jax.random.normal(ks[1], (rows, d))
        w = jax.random.normal(ks[2], (d,))
        b = jax.random.normal(ks[3], (d,))

        fused = jax.jit(lambda x, r, w, b: fused_dropout_residual_layernorm_ref(
            x, r, w, b, 7, dropout_p=0.1))
        unf = jax.jit(lambda x, r, w, b: unfused(x, r, w, b, 7, 0.1))
        us_f = measure_cell(fused, x, r, w, b)["us"]
        us_u = measure_cell(unf, x, r, w, b)["us"]
        # modeled bytes from perf_model (the same accounting select_fusion
        # ranks plans with) — not hand-computed constants
        bytes_fused = pm.dropout_residual_ln_traffic(rows, d, fused=True)
        bytes_unfused = pm.dropout_residual_ln_traffic(rows, d, fused=False)
        # the real Pallas kernel, interpret mode (correctness, not timing)
        o_k, r_k = dropout_residual_layernorm(x, r, w, b, 7, dropout_p=0.1,
                                              mode="pallas_interpret")
        o_r, r_r = fused(x, r, w, b)
        kernel_err = max(float(jnp.abs(o_k - o_r).max()),
                         float(jnp.abs(r_k - r_r).max()))
        emit(f"fused_dropout_resid_ln_s{seq}", us_f,
             f"modeled_v5e_us={bytes_fused / hbm_bw * 1e6:.1f};"
             f"modeled_fused_mb={bytes_fused / 2**20:.1f};"
             f"modeled_unfused_mb={bytes_unfused / 2**20:.1f};"
             f"modeled_speedup={bytes_unfused / bytes_fused:.2f}x;"
             f"cpu_xla_speedup={us_u / us_f:.2f}x;"
             f"pallas_max_err={kernel_err:.2e}")

        # rope: batch 16, heads 16, head dim 128
        bsz, heads, hd = 2, 16, 128
        xq = jax.random.normal(ks[0], (bsz, heads, seq, hd))
        sin, cos = rope_tables(jnp.arange(seq), hd)
        fn = jax.jit(lambda x: rope_ref(x, sin, cos))
        us = measure_cell(fn, xq)["us"]
        bytes_fused = pm.rope_traffic(bsz, heads, seq, hd, fused=True)
        bytes_unfused = pm.rope_traffic(bsz, heads, seq, hd, fused=False)
        out_k = rope(xq, sin, cos, mode="pallas_interpret")
        rope_err = float(jnp.abs(out_k - fn(xq)).max())
        emit(f"rope_s{seq}", us,
             f"modeled_v5e_us={bytes_fused / hbm_bw * 1e6:.1f};"
             f"modeled_fused_mb={bytes_fused / 2**20:.1f};"
             f"modeled_unfused_mb={bytes_unfused / 2**20:.1f};"
             f"modeled_speedup={bytes_unfused / bytes_fused:.2f}x;"
             f"pallas_max_err={rope_err:.2e}")


if __name__ == "__main__":
    main()
