"""Paper Fig. 9: memory-bound kernels — fused dropout-residual-layernorm and
RoPE (batch 16, heads 16, head dim 128 per the paper).

Derived: achievable bandwidth fraction on v5e. The fused kernel moves exactly
2 reads + 2 writes of the activation; the unfused chain moves 3 reads +
3 writes plus a mask read/write — the fusion factor is the paper's win,
reproduced here as measured CPU time (fused jnp vs unfused jnp) and modeled
v5e time (bytes / 819 GB/s).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_norm import (dropout_residual_layernorm,
                                      fused_dropout_residual_layernorm_ref)
from repro.kernels.fused_norm.ref import dropout_keep_mask_ref
from repro.kernels.rope import rope_ref, rope_tables
from repro.launch.roofline import HBM_BW
from .common import time_fn, emit


def unfused(x, r, w, b, seed, p):
    """The torch-eager equivalent: separate dropout, add, layernorm."""
    keep = dropout_keep_mask_ref(seed, x.shape, p)          # mask materialized
    xd = jnp.where(keep, x / (1 - p), 0.0)
    resid = r + xd
    mean = jnp.mean(resid, axis=1, keepdims=True)
    var = jnp.var(resid, axis=1, keepdims=True)
    out = (resid - mean) * jax.lax.rsqrt(var + 1e-5) * w + b
    return out, resid


def main() -> None:
    d = 2048  # 16 heads x 128
    for seq in (2048, 4096, 8192):
        rows = seq
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (rows, d))
        r = jax.random.normal(ks[1], (rows, d))
        w = jax.random.normal(ks[2], (d,))
        b = jax.random.normal(ks[3], (d,))

        fused = jax.jit(lambda x, r, w, b: fused_dropout_residual_layernorm_ref(
            x, r, w, b, 7, dropout_p=0.1))
        unf = jax.jit(lambda x, r, w, b: unfused(x, r, w, b, 7, 0.1))
        us_f = time_fn(fused, x, r, w, b)
        us_u = time_fn(unf, x, r, w, b)
        bytes_fused = 4 * rows * d * 4      # 2R + 2W, mask generated in-kernel
        bytes_unfused = 7 * rows * d * 4    # dropout RW + add RRW + LN RW
        modeled_us = bytes_fused / HBM_BW * 1e6
        emit(f"fused_dropout_resid_ln_s{seq}", us_f,
             f"modeled_v5e_us={modeled_us:.1f};"
             f"modeled_speedup={bytes_unfused / bytes_fused:.2f}x;"
             f"cpu_xla_speedup={us_u / us_f:.2f}x")

        # rope: batch 16, heads 16, head dim 128
        xq = jax.random.normal(ks[0], (2, 16, seq, 128))
        sin, cos = rope_tables(jnp.arange(seq), 128)
        fn = jax.jit(lambda x: rope_ref(x, sin, cos))
        us = time_fn(fn, xq)
        bytes_moved = 2 * xq.size * 4
        emit(f"rope_s{seq}", us,
             f"modeled_v5e_us={bytes_moved / HBM_BW * 1e6:.1f}")


if __name__ == "__main__":
    main()
