"""Run every benchmark (one per paper table/figure).

Prints ``name,us_per_call,derived`` CSV. us_per_call is the measured XLA-CPU
reference path; derived carries the modeled TPU-v5e reproduction numbers
(this container has no TPU — see DESIGN.md §7 / EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import sys
import traceback

from . import (bench_gemm, bench_attention_fwd, bench_attention_bwd,
               bench_memory_bound, bench_schedules, bench_grid_swizzle)

BENCHES = [
    ("Fig6_gemm", bench_gemm.main),
    ("Fig7_attention_fwd", bench_attention_fwd.main),
    ("Fig8_attention_bwd", bench_attention_bwd.main),
    ("Fig9_memory_bound", bench_memory_bound.main),
    ("Tab2_Tab3_schedules", bench_schedules.main),
    ("Tab4_grid_swizzle", bench_grid_swizzle.main),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, fn in BENCHES:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
