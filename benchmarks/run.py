"""Run every benchmark (one per paper table/figure).

Prints ``name,us_per_call,derived`` CSV. us_per_call is the measured XLA-CPU
reference path; derived carries the modeled TPU-v5e reproduction numbers
(this container has no TPU — see DESIGN.md §7 / EXPERIMENTS.md §Roofline).

Each bench also writes a machine-readable ``BENCH_<key>.json`` (rows +
parsed derived fields + a ``telemetry`` block from the launch journal;
directory from ``$BENCH_OUT``, default cwd) so the perf trajectory can be
tracked across commits — CI uploads them as artifacts. Beside each bench
JSON land ``TRACE_<key>.json`` (Chrome-trace/Perfetto, load at
https://ui.perfetto.dev) and ``COUNTERS_<key>.json`` (flat counters),
validated in CI by ``tools/trace_check.py``.
"""
from __future__ import annotations

import sys
import traceback

from . import (bench_gemm, bench_attention_fwd, bench_attention_bwd,
               bench_attention_fusion, bench_calibration, bench_decode,
               bench_distributed, bench_fused_mlp, bench_memory_bound,
               bench_schedules, bench_grid_swizzle, bench_serve)
from .common import begin_capture, end_capture, write_bench_json

# (display name, json key, entry point)
BENCHES = [
    ("Fig6_gemm", "gemm", bench_gemm.main),
    ("Fig7_attention_fwd", "attention_fwd", bench_attention_fwd.main),
    ("Fig8_attention_bwd", "attention_bwd", bench_attention_bwd.main),
    ("Fig7b_attention_fusion", "attention_fusion",
     bench_attention_fusion.main),
    ("Fig9_memory_bound", "memory_bound", bench_memory_bound.main),
    ("Fig9b_decode", "decode", bench_decode.main),
    ("Fig9c_fused_mlp", "fused_mlp", bench_fused_mlp.main),
    ("Tab2_Tab3_schedules", "schedules", bench_schedules.main),
    ("Tab4_grid_swizzle", "grid_swizzle", bench_grid_swizzle.main),
    ("Serve_fastpath", "serve", bench_serve.main),
    ("Sec16_distributed", "distributed", bench_distributed.main),
    ("Sec6_calibration", "calibration", bench_calibration.main),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, key, fn in BENCHES:
        print(f"# --- {name} ---")
        begin_capture()
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        finally:
            path = write_bench_json(key, end_capture())
            print(f"# wrote {path}")
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
