"""Paper Tab. 2 + Tab. 3: scheduling patterns.

Tab. 2 reproduction — wave specialization's producer VMEM tax shrinks the
feasible output tile and with it arithmetic intensity/TFLOPs; output tile
size dominates. Tab. 3 reproduction — the autotuner's full candidate set
(schedule × pipeline depth × traversal) on GEMM and attention, replacing the
old private PINGPONG/INTERLEAVE lists; the selected policy is marked. All
numbers are the analytic v5e pipeline model (no TPU in this container);
the structure mirrors the paper's tables.
"""
from __future__ import annotations

from repro.core import autotune
from repro.core import perf_model as pm
from repro.core import tiles
from repro.core.schedule import PINGPONG, Schedule
from .common import emit, gemm_candidate_sweep


def main() -> None:
    # --- Tab. 2 analogue: producers tax fast memory -> smaller output tile.
    # FINDING: on v5e the constraint does NOT bind — 128 MiB VMEM holds the
    # ridge-point tile (512x512) with room to spare at any producer tax, so
    # wave specialization would not cost TFLOPs here the way it does on
    # MI355X. The mechanism reappears verbatim under an AMD-LDS-scale fast
    # memory (4 MiB), which we also report to show the paper's principle
    # generalizes with a different constant.
    for fast_bytes, hw in ((tiles.VMEM_BYTES, "v5e_vmem128MiB"),
                           (4 * 2**20, "lds_scale4MiB")):
        for producer_frac, label in ((0.0, "0P"), (0.2, "2P"), (0.33, "4P"),
                                     (0.5, "8P")):
            budget = int(fast_bytes * (1 - producer_frac))
            bm, bn = pm.best_output_tile(budget, n_buffers=2, block_k=512)
            sched = Schedule(f"ws_{label}", 2, bm, bn, 512)
            m = pm.gemm_step_model(sched, k_total=8192)
            emit(f"tab2_{hw}_producer_{label}_tile{bm}x{bn}", 0.0,
                 f"modeled_tflops={m['modeled_tflops']:.0f};"
                 f"ai={m['arithmetic_intensity']:.0f};bound={m['bound']};"
                 f"constraint_binds={'yes' if (bm, bn) != (512, 512) else 'no'}")

    # --- output tile sweep (the paper's core Tab. 2 conclusion) ---
    for bm, bn in ((128, 128), (128, 256), (192, 256), (256, 256),
                   (384, 384), (512, 512)):
        sched = Schedule("tile", 2, bm, bn, 512)
        m = pm.gemm_step_model(sched, k_total=8192)
        emit(f"tab2_output_tile_{bm}x{bn}", 0.0,
             f"modeled_tflops={m['modeled_tflops']:.0f};"
             f"ai={m['arithmetic_intensity']:.0f};bound={m['bound']}")

    # --- Tab. 3 analogue: the autotuner's GEMM candidate set, scored ---
    n = 8192
    sig = autotune.OpSignature("gemm", (n, n, n))
    for pol, selected in gemm_candidate_sweep((n, n, n)):
        score = autotune.score_policy(sig, pol)
        m = pm.gemm_step_model(pol.schedule, k_total=n)
        emit(f"tab3_gemm_{pol.block_m}x{pol.block_n}x{pol.block_k}"
             f"_nbuf{pol.n_buffers}", 0.0,
             f"modeled_tflops={m['modeled_tflops']:.0f};"
             f"vmem_mib={m['vmem_bytes'] / 2**20:.1f};"
             f"modeled_time_ms={score.time_s * 1e3:.2f};"
             f"selected={'yes' if selected else 'no'}")

    # --- Tab. 3 attention: the autotuner's candidate set for a Fig. 7 shape
    attn_sig = autotune.OpSignature("attention_fwd", (1, 16, 8192, 8192, 128))
    attn_chosen = autotune.select_policy("attention_fwd",
                                         (1, 16, 8192, 8192, 128))
    for pol in autotune.candidate_policies(attn_sig):
        m = pm.attention_step_model(block_q=pol.block_q,
                                    block_kv=pol.block_kv, head_dim=128,
                                    seq_len=8192, causal=False)
        sel = "yes" if (pol.block_q, pol.block_kv) == \
            (attn_chosen.block_q, attn_chosen.block_kv) else "no"
        emit(f"tab3_attn_q{pol.block_q}_kv{pol.block_kv}", 0.0,
             f"modeled_tflops={m['modeled_tflops']:.0f};bound={m['bound']};"
             f"selected={sel}")

    # --- Tab. 1 analogue: pinned scratch accumulators ---
    # No register file on TPU; the pinned fp32 VMEM accumulator is structural
    # (always on) — report its budget share for the PINGPONG GEMM tile.
    acc = PINGPONG.block_m * PINGPONG.block_n * 4
    emit("tab1_pinned_scratch_accumulator", 0.0,
         f"acc_bytes={acc};fraction_of_vmem={acc / tiles.VMEM_BYTES:.3f}")


if __name__ == "__main__":
    main()
