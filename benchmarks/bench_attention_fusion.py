"""Attention fusion-plan sweep (DESIGN.md §12; paper Fig. 7/8 regime).

The paper's headline attention cells — d=64 forward and GQA backward, where
HipKittens beats the baselines 1.2–2.4x — are exactly where the flash
megakernel's traffic advantage over the eager materialized-scores chain is
largest (unfused/fused ratio ~ 4·S/d). This bench sweeps those cells at the
paper's shapes (batch 16, 16/64 q heads, head dim 64/128) and reports, per
cell and per direction (fwd / training bwd), the modeled HBM traffic of the
fused flash plan vs the unfused eager chain and which plan
``autotune.select_fusion`` picks from ``dma_bytes`` alone. Epilogue columns
(``softcap_*``) re-score the same cell with the gemma2 tanh cap in the
chain: the cap is free on the fused side (vector work on resident tiles)
and adds a score-matrix read+write pass on the eager side.

Rows land in ``BENCH_attention_fusion.json`` via benchmarks.run; CI asserts
``traffic_reduction >= 1.2`` on every d=64 forward cell and every GQA
backward cell (the paper's two headline regimes).

Also validates the fused interpret-mode path end to end: flash + epilogue
vs the jnp reference on a small shape, and jax.grad parity of the
saved-preact backward, with the eager reference timed on CPU for scale.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.kernels.attention import attention, attention_ref
from .common import measure_cell, emit

CELLS = (("mha", 16, 16, 128), ("mha_d64", 16, 16, 64),
         ("gqa", 64, 8, 128), ("gqa_d64", 64, 8, 64))


def main() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    seqs = (2048, 4096) if smoke else (2048, 4096, 8192, 16384)
    for name, h, hkv, d in CELLS:
        for seq in seqs:
            shape = (16, h, hkv, seq, seq, d)
            for direction, kw in (("fwd", {}), ("bwd", {"backward": True})):
                plan = autotune.select_fusion("attention", shape, "bfloat16",
                                              causal=True, **kw)
                cap = autotune.select_fusion("attention", shape, "bfloat16",
                                             causal=True, softcap=True, **kw)
                emit(f"attn_fusion_{name}_s{seq}_{direction}", 0.0,
                     f"plan={plan['plan']};"
                     f"fused_mb={plan['fused_bytes'] / 2**20:.1f};"
                     f"unfused_mb={plan['unfused_bytes'] / 2**20:.1f};"
                     f"traffic_reduction={plan['traffic_reduction']:.2f};"
                     f"softcap_plan={cap['plan']};"
                     f"softcap_traffic_reduction="
                     f"{cap['traffic_reduction']:.2f};"
                     f"modeled_fused_us={plan['fused']['time_s'] * 1e6:.1f};"
                     f"modeled_unfused_us="
                     f"{plan['unfused']['time_s'] * 1e6:.1f};"
                     f"bound={plan['fused']['bound']}")

    # end-to-end check at small scale: fused flash + softcap/sink epilogue
    # (interpret mode) vs the eager jnp reference, fwd and grad
    b, h, hkv, s, d = 1, 4, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32) * 0.5
    sinks = jax.random.normal(ks[3], (h,), jnp.float32)
    ref_fn = jax.jit(lambda q, k, v, sinks: attention_ref(
        q, k, v, causal=True, softcap=20.0, sinks=sinks))
    us_ref = measure_cell(ref_fn, q, k, v, sinks, warmup=2, iters=5)["us"]
    out = attention(q, k, v, causal=True, softcap=20.0, sinks=sinks,
                    mode="pallas_interpret")
    err = float(jnp.abs(out - ref_fn(q, k, v, sinks)).max())
    assert err < 1e-4, err
    emit(f"attn_fusion_pallas_check_s{s}_d{d}", us_ref,
         f"max_err={err:.2e};plan="
         f"{autotune.select_fusion('attention', (b, h, hkv, s, s, d), 'float32', causal=True)['plan']}")

    # saved-preact backward (DESIGN.md §12): jax.grad through the fused
    # kernel vs autodiff of the eager reference, dsinks included
    def loss(fn):
        return lambda q, k, v, sinks: jnp.sum(
            fn(q, k, v, sinks) ** 2)

    g_kern = jax.grad(loss(lambda q, k, v, sinks: attention(
        q, k, v, causal=True, softcap=20.0, sinks=sinks,
        mode="pallas_interpret")), argnums=(0, 1, 2, 3))(q, k, v, sinks)
    g_ref = jax.grad(loss(ref_fn), argnums=(0, 1, 2, 3))(q, k, v, sinks)
    gerr = max(float(jnp.abs(a - b_).max()) for a, b_ in zip(g_kern, g_ref))
    assert gerr < 1e-3, gerr
    bwd_plan = autotune.select_fusion("attention", (b, h, hkv, s, s, d),
                                      "float32", causal=True, backward=True)
    emit(f"attn_fusion_bwd_check_s{s}_d{d}", 0.0,
         f"max_grad_err={gerr:.2e};bwd_plan={bwd_plan['plan']};"
         f"bwd_traffic_reduction={bwd_plan['traffic_reduction']:.2f}")


if __name__ == "__main__":
    main()
