"""Paper Fig. 7/16/17: attention forward (MHA + GQA, causal/non-causal,
head dim 64/128) at the paper's shapes (batch 16, 16/64 q heads).

Derived: modeled v5e TFLOP/s from the flash pipeline model; measured: the
chunked-XLA reference fwd at a scaled shape (CPU feasibility).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core import perf_model as pm
from repro.kernels.attention import attention
from .common import measure_cell, emit


def main() -> None:
    # paper configuration sweep -> modeled numbers at full scale
    for name, h, hkv, d in (("mha", 16, 16, 128), ("mha_d64", 16, 16, 64),
                            ("gqa", 64, 8, 128), ("gqa_d64", 64, 8, 64)):
        for seq in (2048, 4096, 8192, 16384):
            for causal in (False, True):
                m = pm.attention_step_model(
                    block_q=128, block_kv=128, head_dim=d, seq_len=seq,
                    causal=causal, dtype_bytes=2)
                tag = f"attn_fwd_{name}_s{seq}_{'causal' if causal else 'full'}"
                # measured: scaled-down reference path on CPU
                b_s, s_s = 1, min(seq, 1024)
                ks = jax.random.split(jax.random.PRNGKey(0), 3)
                q = jax.random.normal(ks[0], (b_s, 4, s_s, d), jnp.float32)
                k = jax.random.normal(ks[1], (b_s, max(1, 4 * hkv // h), s_s, d))
                v = jax.random.normal(ks[2], k.shape)
                fn = jax.jit(lambda q, k, v: attention(
                    q, k, v, causal=causal, mode="reference"))
                us = measure_cell(fn, q, k, v, warmup=2, iters=5)["us"]
                # fusion plan from modeled dma_bytes (DESIGN.md §12): flash
                # megakernel vs materialized-scores eager chain
                plan = autotune.select_fusion(
                    "attention", (16, h, hkv, seq, seq, d), "bfloat16",
                    causal=causal)
                emit(tag, us, f"modeled_tflops={m['modeled_tflops']:.0f};"
                     f"bound={m['bound']};plan={plan['plan']};"
                     f"traffic_reduction={plan['traffic_reduction']:.2f}")


if __name__ == "__main__":
    main()
